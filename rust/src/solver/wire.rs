//! Zero-dependency binary wire protocol for [`crate::solver::service`].
//!
//! # Serving over the network: the frame grammar
//!
//! Everything on the wire is a *frame*:
//!
//! ```text
//! frame    := len:u32le  kind:u8  payload[len-1]
//! ```
//!
//! `len` counts the kind byte plus the payload, so an empty payload is
//! `len == 1`; `len == 0` is malformed and `len > MAX_FRAME_LEN` is
//! rejected before any allocation. All integers are little-endian;
//! strings are `len:u32le` followed by that many UTF-8 bytes; vectors
//! of `u32` are `len:u32le` followed by the elements. Every decoder is
//! *checked*: short payloads, out-of-range tags, non-UTF-8 strings, and
//! trailing garbage return a [`WireError`] — malformed input can never
//! panic the peer, which is what lets the server answer garbage with a
//! typed [`Frame::Error`] and keep serving.
//!
//! Frame kinds (the `kind` byte):
//!
//! | kind | frame            | direction | payload                          |
//! |------|------------------|-----------|----------------------------------|
//! | 0x01 | `Hello`          | C → S     | magic `u32`, client version `u16`|
//! | 0x02 | `HelloAck`       | S → C     | negotiated version `u16`         |
//! | 0x03 | `Submit`         | C → S     | req id, problem, options         |
//! | 0x04 | `Solution`       | S → C     | req id, solution                 |
//! | 0x05 | `Error`          | S → C     | req id (0 = connection), code, detail |
//! | 0x06 | `Cancel`         | C → S     | req id                           |
//! | 0x07 | `StatsRequest`   | C → S     | —                                |
//! | 0x08 | `StatsReply`     | S → C     | full [`ServiceStats`] snapshot   |
//!
//! **Version negotiation.** A connection opens with `Hello{magic,
//! version}`; the server rejects a wrong magic outright, otherwise
//! replies `HelloAck{min(client, server)}` and both sides speak that
//! version. Version 1 is the only version today; the handshake exists
//! so a future frame-layout change can keep old clients working.
//!
//! **Problems on the wire.** A [`Problem`] travels as its kind tag, the
//! PVC budget `k`, and the graph in CSR form — `n`, `n + 1` row
//! pointers, then `row_ptr[n]` adjacency entries (each undirected edge
//! appears twice, exactly the in-memory layout). The decoder
//! re-validates everything [`Graph::from_csr_parts`] debug-asserts —
//! monotone row pointers, strictly sorted rows, in-range endpoints, no
//! self loops, symmetry — because the bytes come from an untrusted
//! socket, then rebuilds the graph with `from_csr_parts` so the engine
//! sees exactly the structure an in-process caller would have built.
//!
//! **Solutions on the wire** carry the objective, feasibility, the
//! optional witness (verbatim vertex ids) and its verification verdict,
//! the termination reason, the failure message if any, and a small
//! stats subset (tree nodes, component branches, induced subproblems,
//! memo traffic, prep sizes) — enough for a remote driver to print the
//! same table batch mode prints locally.
//!
//! [`SubmitError`] maps onto typed error frames
//! ([`ErrorCode::QueueFull`] / [`ErrorCode::QuotaExceeded`] /
//! [`ErrorCode::MemoryPressure`]) so remote callers see the same
//! backpressure vocabulary in-process callers get, and
//! [`ErrorCode::submit_error`] folds them back. The TCP server that
//! speaks this protocol lives in [`crate::solver::server`].

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::Duration;

use crate::graph::Graph;

use super::autotune::AutotuneStats;
use super::memo::MemoStats;
use super::service::{
    AdmissionStats, ClassStats, JobOptions, Lane, PoolStats, Problem, ProblemKind, ServiceStats,
    Solution, SubmitError, Termination,
};

/// First bytes of every connection: `b"CAVC"` read as a little-endian
/// `u32`. A peer that opens with anything else is not speaking this
/// protocol and is rejected before any state is allocated.
pub const WIRE_MAGIC: u32 = u32::from_le_bytes(*b"CAVC");

/// Protocol version spoken by this build. The handshake negotiates
/// `min(client, server)`.
pub const PROTOCOL_VERSION: u16 = 1;

/// Upper bound on `len` of a single frame (64 MiB). Checked before the
/// payload is allocated, so a hostile length prefix cannot balloon
/// memory; a graph too large for one frame is a connection-fatal
/// [`WireError::Oversized`].
pub const MAX_FRAME_LEN: u32 = 64 << 20;

/// Frame-kind discriminants (the `kind` byte after the length prefix).
pub mod kind {
    /// Client → server version handshake opener.
    pub const HELLO: u8 = 0x01;
    /// Server → client handshake reply carrying the negotiated version.
    pub const HELLO_ACK: u8 = 0x02;
    /// Client → server job submission (request id + problem + options).
    pub const SUBMIT: u8 = 0x03;
    /// Server → client finished-job digest.
    pub const SOLUTION: u8 = 0x04;
    /// Server → client typed error (admission shed, protocol fault…).
    pub const ERROR: u8 = 0x05;
    /// Client → server cancellation of an outstanding request.
    pub const CANCEL: u8 = 0x06;
    /// Client → server stats scrape request.
    pub const STATS_REQUEST: u8 = 0x07;
    /// Server → client [`super::ServiceStats`] snapshot.
    pub const STATS_REPLY: u8 = 0x08;
}

/// Why a frame could not be decoded (or read).
#[derive(Debug)]
pub enum WireError {
    /// The underlying socket/stream failed (includes EOF mid-frame and
    /// read timeouts, surfaced by the transport).
    Io(std::io::Error),
    /// The payload ended in the middle of a field.
    Truncated,
    /// The payload decoded but left unconsumed bytes.
    Trailing(usize),
    /// A field held an out-of-range or inconsistent value.
    Malformed(&'static str),
    /// The length prefix exceeded [`MAX_FRAME_LEN`]. Connection-fatal:
    /// the oversized payload was not consumed, so the stream is out of
    /// sync.
    Oversized(u32),
    /// An unknown frame-kind byte.
    UnknownKind(u8),
    /// The `Hello` magic was wrong — the peer is not speaking this
    /// protocol.
    BadMagic(u32),
    /// The peer requested protocol version 0 (reserved / unsupported).
    Version(u16),
    /// A string field was not valid UTF-8.
    BadUtf8,
}

impl WireError {
    /// Whether the stream is still framed after this error: the decoder
    /// consumed exactly the declared frame, so the connection can reply
    /// with a typed error frame and keep going. I/O failures and
    /// oversized length prefixes are not recoverable — the stream
    /// position is unknown.
    pub fn recoverable(&self) -> bool {
        !matches!(self, WireError::Io(_) | WireError::Oversized(_))
    }

    /// The wire error code a server reports for this decode failure.
    pub fn code(&self) -> ErrorCode {
        match self {
            WireError::Oversized(_) => ErrorCode::Oversized,
            WireError::Version(_) => ErrorCode::UnsupportedVersion,
            _ => ErrorCode::Malformed,
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o: {e}"),
            WireError::Truncated => write!(f, "frame truncated mid-field"),
            WireError::Trailing(n) => write!(f, "{n} trailing bytes after frame"),
            WireError::Malformed(what) => write!(f, "malformed frame: {what}"),
            WireError::Oversized(len) => {
                write!(f, "frame length {len} exceeds cap {MAX_FRAME_LEN}")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k:#04x}"),
            WireError::BadMagic(m) => write!(f, "bad hello magic {m:#010x}"),
            WireError::Version(v) => write!(f, "unsupported protocol version {v}"),
            WireError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Typed error codes carried by [`Frame::Error`]. The first three are
/// the [`SubmitError`] backpressure vocabulary; the rest are protocol-
/// and connection-level failures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// [`SubmitError::QueueFull`] — the admission queue bounced the job.
    QueueFull,
    /// [`SubmitError::QuotaExceeded`] — the tenant is at quota.
    QuotaExceeded,
    /// [`SubmitError::MemoryPressure`] — the watchdog hard limit shed
    /// the job.
    MemoryPressure,
    /// The peer sent a frame that did not decode.
    Malformed,
    /// The peer sent a frame longer than [`MAX_FRAME_LEN`].
    Oversized,
    /// The peer requested an unsupported protocol version.
    UnsupportedVersion,
    /// The server is at its connection cap.
    ConnLimit,
    /// A duplicate request id or a frame the server does not accept in
    /// the current connection state.
    Protocol,
}

impl ErrorCode {
    fn as_u8(self) -> u8 {
        match self {
            ErrorCode::QueueFull => 1,
            ErrorCode::QuotaExceeded => 2,
            ErrorCode::MemoryPressure => 3,
            ErrorCode::Malformed => 16,
            ErrorCode::Oversized => 17,
            ErrorCode::UnsupportedVersion => 18,
            ErrorCode::ConnLimit => 19,
            ErrorCode::Protocol => 20,
        }
    }

    fn from_u8(v: u8) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::QueueFull,
            2 => ErrorCode::QuotaExceeded,
            3 => ErrorCode::MemoryPressure,
            16 => ErrorCode::Malformed,
            17 => ErrorCode::Oversized,
            18 => ErrorCode::UnsupportedVersion,
            19 => ErrorCode::ConnLimit,
            20 => ErrorCode::Protocol,
            _ => return None,
        })
    }

    /// Fold an admission error code back into the in-process
    /// [`SubmitError`] it mirrors; `None` for protocol-level codes.
    pub fn submit_error(self) -> Option<SubmitError> {
        match self {
            ErrorCode::QueueFull => Some(SubmitError::QueueFull),
            ErrorCode::QuotaExceeded => Some(SubmitError::QuotaExceeded),
            ErrorCode::MemoryPressure => Some(SubmitError::MemoryPressure),
            _ => None,
        }
    }
}

impl From<SubmitError> for ErrorCode {
    fn from(e: SubmitError) -> ErrorCode {
        match e {
            SubmitError::QueueFull => ErrorCode::QueueFull,
            SubmitError::QuotaExceeded => ErrorCode::QuotaExceeded,
            SubmitError::MemoryPressure => ErrorCode::MemoryPressure,
        }
    }
}

/// The [`JobOptions`] subset that travels with a remote submit: lane
/// pin, deadline, tenant id, witness extraction, memo opt-in/out.
/// Per-job `SolverConfig` overrides, retry policies, and fault plans
/// stay server-side policy.
#[derive(Debug, Clone, Default)]
pub struct WireOptions {
    /// Pin the job to a QoS lane (`None` = size-classified).
    pub lane: Option<Lane>,
    /// Per-job wall-clock budget. The clock starts at admission on the
    /// *server*, so network transit does not count against it.
    pub timeout: Option<Duration>,
    /// Tenant id for quota accounting.
    pub tenant: Option<String>,
    /// Ask the server to extract and verify a witness.
    pub extract_witness: bool,
    /// Per-job memo-cache override (`None` = server default).
    pub memo: Option<bool>,
}

impl WireOptions {
    /// The in-process [`JobOptions`] this remote submission stands for.
    pub fn job_options(&self) -> JobOptions {
        JobOptions {
            timeout: self.timeout,
            extract_witness: self.extract_witness,
            priority: self.lane,
            tenant: self.tenant.clone(),
            memo: self.memo,
            ..JobOptions::default()
        }
    }
}

/// A remote job submission: client-chosen request id (unique per
/// connection), the problem, and the options subset.
#[derive(Debug, Clone)]
pub struct SubmitRequest {
    /// Client-chosen id echoed on the reply; must be unique among this
    /// connection's outstanding requests and non-zero (0 is reserved
    /// for connection-level errors).
    pub req_id: u64,
    /// The decoded problem (graph rebuilt via [`Graph::from_csr_parts`]).
    pub problem: Problem,
    /// The remote options subset.
    pub opts: WireOptions,
}

/// The [`Solution`] subset that travels back to a remote client.
#[derive(Debug, Clone)]
pub struct WireSolution {
    /// The request id this answers.
    pub req_id: u64,
    /// Which problem kind this answers.
    pub problem: ProblemKind,
    /// Objective value (see [`Solution::objective`]).
    pub objective: u32,
    /// PVC feasibility (always true for MVC/MIS).
    pub feasible: bool,
    /// Witness vertex set, if extraction was requested and produced one.
    pub witness: Option<Vec<u32>>,
    /// Whether the server verified the witness edge-by-edge.
    pub witness_verified: Option<bool>,
    /// Why the job stopped.
    pub termination: Termination,
    /// Captured panic message for failed/recovered jobs.
    pub failure: Option<String>,
    /// Server-side wall clock from admission to finalization.
    pub elapsed: Duration,
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Nodes that branched on components.
    pub component_branches: u64,
    /// Split components materialized as induced subproblems.
    pub induced_subproblems: u64,
    /// Component dispatches that consulted the cross-job memo cache.
    pub memo_lookups: u64,
    /// Memo lookups that skipped the subtree.
    pub memo_hits: u64,
    /// Residual |V| after root reduction.
    pub n_residual: u32,
    /// Vertices forced into the cover at the root.
    pub forced: u32,
    /// Greedy upper bound at the root.
    pub greedy_ub: u32,
}

impl WireSolution {
    /// Project a service [`Solution`] onto the wire subset.
    pub fn from_solution(req_id: u64, sol: &Solution) -> WireSolution {
        WireSolution {
            req_id,
            problem: sol.problem,
            objective: sol.objective,
            feasible: sol.feasible,
            witness: sol.witness.clone(),
            witness_verified: sol.witness_verified,
            termination: sol.termination,
            failure: sol.failure.clone(),
            elapsed: sol.elapsed,
            tree_nodes: sol.stats.tree_nodes,
            component_branches: sol.stats.component_branches,
            induced_subproblems: sol.stats.induced_subproblems,
            memo_lookups: sol.stats.memo_lookups,
            memo_hits: sol.stats.memo_hits,
            n_residual: sol.prep.n_residual as u32,
            forced: sol.prep.forced as u32,
            greedy_ub: sol.prep.greedy_ub,
        }
    }

    /// Whether the job stopped because its deadline fired (mirrors
    /// [`Solution::timed_out`]).
    pub fn timed_out(&self) -> bool {
        self.termination == Termination::DeadlineExpired
    }
}

/// A typed error reply ([`Frame::Error`]).
#[derive(Debug, Clone)]
pub struct WireErrorFrame {
    /// The request this rejects, or 0 for a connection-level error.
    pub req_id: u64,
    /// The typed code.
    pub code: ErrorCode,
    /// Human-readable detail.
    pub detail: String,
}

/// One decoded protocol frame. See the module docs for the grammar.
#[derive(Debug, Clone)]
pub enum Frame {
    /// Client handshake: magic + highest version the client speaks.
    Hello {
        /// Must equal [`WIRE_MAGIC`].
        magic: u32,
        /// Highest protocol version the client speaks (≥ 1).
        version: u16,
    },
    /// Server handshake reply: the negotiated version.
    HelloAck {
        /// `min(client, server)` version; all further frames use it.
        version: u16,
    },
    /// A job submission.
    Submit(SubmitRequest),
    /// A finished job's result (exactly one per admitted submit).
    Solution(Box<WireSolution>),
    /// A typed rejection or protocol error.
    Error(WireErrorFrame),
    /// Cancel an outstanding request; its `Solution` still arrives,
    /// terminated [`Termination::Cancelled`] (anytime result).
    Cancel {
        /// The request to cancel.
        req_id: u64,
    },
    /// Ask for a [`ServiceStats`] snapshot.
    StatsRequest,
    /// The scrape reply: the full [`VcService::stats`] snapshot
    /// (admission, lanes, watchdog ledger, memo cache), field for field.
    ///
    /// [`VcService::stats`]: super::service::VcService::stats
    StatsReply(Box<ServiceStats>),
}

// ---------------------------------------------------------------------------
// Encoder / decoder primitives
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        // Reserve the 4-byte length slot; patched in `finish`.
        Enc { buf: vec![0u8; 4] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn vec_u32(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.u32(*x);
        }
    }

    fn finish(mut self) -> Vec<u8> {
        let len = (self.buf.len() - 4) as u32;
        self.buf[..4].copy_from_slice(&len.to_le_bytes());
        self.buf
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    /// A `u32` vector whose declared length is validated against the
    /// remaining payload *before* allocating, so a hostile length can
    /// never balloon memory past the (already capped) frame size.
    fn vec_u32(&mut self) -> Result<Vec<u32>, WireError> {
        let len = self.u32()? as usize;
        self.checked_u32s(len)
    }

    fn checked_u32s(&mut self, len: usize) -> Result<Vec<u32>, WireError> {
        if len.checked_mul(4).is_none_or(|bytes| bytes > self.remaining()) {
            return Err(WireError::Truncated);
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    fn done(&self) -> Result<(), WireError> {
        match self.remaining() {
            0 => Ok(()),
            n => Err(WireError::Trailing(n)),
        }
    }
}

// ---------------------------------------------------------------------------
// Domain-type encodings
// ---------------------------------------------------------------------------

fn kind_tag(k: ProblemKind) -> u8 {
    match k {
        ProblemKind::Mvc => 0,
        ProblemKind::Pvc => 1,
        ProblemKind::Mis => 2,
    }
}

fn kind_from_tag(t: u8) -> Result<ProblemKind, WireError> {
    Ok(match t {
        0 => ProblemKind::Mvc,
        1 => ProblemKind::Pvc,
        2 => ProblemKind::Mis,
        _ => return Err(WireError::Malformed("problem kind tag")),
    })
}

fn termination_tag(t: Termination) -> u8 {
    match t {
        Termination::Complete => 0,
        Termination::DeadlineExpired => 1,
        Termination::Cancelled => 2,
        Termination::Failed => 3,
        Termination::Recovered => 4,
    }
}

fn termination_from_tag(t: u8) -> Result<Termination, WireError> {
    Ok(match t {
        0 => Termination::Complete,
        1 => Termination::DeadlineExpired,
        2 => Termination::Cancelled,
        3 => Termination::Failed,
        4 => Termination::Recovered,
        _ => return Err(WireError::Malformed("termination tag")),
    })
}

fn encode_graph(e: &mut Enc, g: &Graph) {
    let n = g.num_vertices();
    e.u32(n as u32);
    let mut acc = 0u32;
    e.u32(acc);
    for v in 0..n as u32 {
        acc += g.degree(v);
        e.u32(acc);
    }
    for v in 0..n as u32 {
        for u in g.neighbors(v) {
            e.u32(*u);
        }
    }
}

/// Decode and *fully validate* a CSR graph: the checks mirror what
/// [`Graph::from_csr_parts`] debug-asserts, but run unconditionally —
/// wire input is untrusted, and release builds skip debug assertions.
fn decode_graph(d: &mut Dec<'_>) -> Result<Graph, WireError> {
    let n = d.u32()? as usize;
    let row_ptr = d.checked_u32s(n + 1)?;
    if row_ptr[0] != 0 {
        return Err(WireError::Malformed("row_ptr[0] != 0"));
    }
    if row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(WireError::Malformed("row pointers not monotone"));
    }
    let adj = d.checked_u32s(row_ptr[n] as usize)?;
    for v in 0..n {
        let row = &adj[row_ptr[v] as usize..row_ptr[v + 1] as usize];
        if row.windows(2).any(|w| w[0] >= w[1]) {
            return Err(WireError::Malformed("adjacency row not strictly sorted"));
        }
        if row.iter().any(|&u| u as usize >= n) {
            return Err(WireError::Malformed("adjacency endpoint out of range"));
        }
        if row.binary_search(&(v as u32)).is_ok() {
            return Err(WireError::Malformed("self loop"));
        }
    }
    // Symmetry: every (v, u) must have a mirror (u, v).
    for v in 0..n {
        for &u in &adj[row_ptr[v] as usize..row_ptr[v + 1] as usize] {
            let mirror = &adj[row_ptr[u as usize] as usize..row_ptr[u as usize + 1] as usize];
            if mirror.binary_search(&(v as u32)).is_err() {
                return Err(WireError::Malformed("asymmetric edge"));
            }
        }
    }
    Ok(Graph::from_csr_parts(row_ptr, adj))
}

fn encode_problem(e: &mut Enc, p: &Problem) {
    e.u8(kind_tag(p.kind()));
    let k = match p {
        Problem::Pvc { k, .. } => *k,
        _ => 0,
    };
    e.u32(k);
    encode_graph(e, p.graph());
}

fn decode_problem(d: &mut Dec<'_>) -> Result<Problem, WireError> {
    let kind = kind_from_tag(d.u8()?)?;
    let k = d.u32()?;
    let g = Arc::new(decode_graph(d)?);
    Ok(match kind {
        ProblemKind::Mvc => Problem::mvc(g),
        ProblemKind::Pvc => Problem::pvc(g, k),
        ProblemKind::Mis => Problem::mis(g),
    })
}

const OPT_WITNESS: u8 = 1 << 0;
const OPT_LANE: u8 = 1 << 1;
const OPT_TIMEOUT: u8 = 1 << 2;
const OPT_TENANT: u8 = 1 << 3;
const OPT_MEMO: u8 = 1 << 4;
const OPT_MEMO_ON: u8 = 1 << 5;

fn encode_options(e: &mut Enc, o: &WireOptions) {
    let mut flags = 0u8;
    if o.extract_witness {
        flags |= OPT_WITNESS;
    }
    if o.lane.is_some() {
        flags |= OPT_LANE;
    }
    if o.timeout.is_some() {
        flags |= OPT_TIMEOUT;
    }
    if o.tenant.is_some() {
        flags |= OPT_TENANT;
    }
    if let Some(on) = o.memo {
        flags |= OPT_MEMO;
        if on {
            flags |= OPT_MEMO_ON;
        }
    }
    e.u8(flags);
    if let Some(lane) = o.lane {
        e.u8(match lane {
            Lane::Latency => 0,
            Lane::Throughput => 1,
        });
    }
    if let Some(t) = o.timeout {
        e.u64(t.as_nanos().min(u64::MAX as u128) as u64);
    }
    if let Some(t) = &o.tenant {
        e.str(t);
    }
}

fn decode_options(d: &mut Dec<'_>) -> Result<WireOptions, WireError> {
    let flags = d.u8()?;
    let lane = if flags & OPT_LANE != 0 {
        Some(match d.u8()? {
            0 => Lane::Latency,
            1 => Lane::Throughput,
            _ => return Err(WireError::Malformed("lane tag")),
        })
    } else {
        None
    };
    let timeout = if flags & OPT_TIMEOUT != 0 {
        Some(Duration::from_nanos(d.u64()?))
    } else {
        None
    };
    let tenant = if flags & OPT_TENANT != 0 { Some(d.str()?) } else { None };
    let memo = if flags & OPT_MEMO != 0 { Some(flags & OPT_MEMO_ON != 0) } else { None };
    Ok(WireOptions { lane, timeout, tenant, extract_witness: flags & OPT_WITNESS != 0, memo })
}

const SOL_WITNESS: u8 = 1 << 0;
const SOL_VERIFIED: u8 = 1 << 1;
const SOL_VERIFIED_OK: u8 = 1 << 2;
const SOL_FAILURE: u8 = 1 << 3;

fn encode_solution(e: &mut Enc, s: &WireSolution) {
    e.u64(s.req_id);
    e.u8(kind_tag(s.problem));
    e.u32(s.objective);
    e.u8(s.feasible as u8);
    e.u8(termination_tag(s.termination));
    e.u64(s.elapsed.as_nanos().min(u64::MAX as u128) as u64);
    let mut flags = 0u8;
    if s.witness.is_some() {
        flags |= SOL_WITNESS;
    }
    if let Some(ok) = s.witness_verified {
        flags |= SOL_VERIFIED;
        if ok {
            flags |= SOL_VERIFIED_OK;
        }
    }
    if s.failure.is_some() {
        flags |= SOL_FAILURE;
    }
    e.u8(flags);
    if let Some(w) = &s.witness {
        e.vec_u32(w);
    }
    if let Some(msg) = &s.failure {
        e.str(msg);
    }
    e.u64(s.tree_nodes);
    e.u64(s.component_branches);
    e.u64(s.induced_subproblems);
    e.u64(s.memo_lookups);
    e.u64(s.memo_hits);
    e.u32(s.n_residual);
    e.u32(s.forced);
    e.u32(s.greedy_ub);
}

fn decode_solution(d: &mut Dec<'_>) -> Result<WireSolution, WireError> {
    let req_id = d.u64()?;
    let problem = kind_from_tag(d.u8()?)?;
    let objective = d.u32()?;
    let feasible = match d.u8()? {
        0 => false,
        1 => true,
        _ => return Err(WireError::Malformed("feasible flag")),
    };
    let termination = termination_from_tag(d.u8()?)?;
    let elapsed = Duration::from_nanos(d.u64()?);
    let flags = d.u8()?;
    let witness = if flags & SOL_WITNESS != 0 { Some(d.vec_u32()?) } else { None };
    let witness_verified =
        if flags & SOL_VERIFIED != 0 { Some(flags & SOL_VERIFIED_OK != 0) } else { None };
    let failure = if flags & SOL_FAILURE != 0 { Some(d.str()?) } else { None };
    Ok(WireSolution {
        req_id,
        problem,
        objective,
        feasible,
        witness,
        witness_verified,
        termination,
        failure,
        elapsed,
        tree_nodes: d.u64()?,
        component_branches: d.u64()?,
        induced_subproblems: d.u64()?,
        memo_lookups: d.u64()?,
        memo_hits: d.u64()?,
        n_residual: d.u32()?,
        forced: d.u32()?,
        greedy_ub: d.u32()?,
    })
}

fn encode_stats(e: &mut Enc, s: &ServiceStats) {
    let p = &s.pool;
    e.u64(p.pushes);
    e.u64(p.injected);
    e.u64(p.pops);
    e.u64(p.shared_pops);
    e.u64(p.steals);
    e.u64(p.steal_retries);
    e.u64(p.parks);
    e.u64(p.backlog as u64);
    let a = &s.admission;
    e.u64(a.queued as u64);
    e.u64(a.live_jobs as u64);
    e.u64(a.rejected);
    e.u64(a.quota_rejected);
    e.u64(a.blocked.as_nanos().min(u64::MAX as u128) as u64);
    e.u64(a.dispatched_latency);
    e.u64(a.dispatched_throughput);
    e.u64(a.live_bytes);
    e.u64(a.mem_rejected);
    e.u64(a.retries);
    e.u64(a.recovered);
    e.u64(a.quarantined);
    for c in [&s.mvc, &s.pvc, &s.mis] {
        e.u64(c.jobs);
        e.u64(c.steals);
        e.u64(c.tree_nodes);
        e.u64(c.delta_children);
        e.u64(c.undo_pops);
        e.u64(c.materializations);
        e.u64(c.memo_lookups);
        e.u64(c.memo_hits);
    }
    let m = &s.memo;
    e.u64(m.lookups);
    e.u64(m.hits);
    e.u64(m.misses);
    e.u64(m.inserts);
    e.u64(m.evictions);
    e.u64(m.bytes);
    e.u64(m.saved_nodes);
    let t = &s.autotune;
    e.u64(t.enabled as u64);
    e.u64(t.epochs);
    e.u64(t.flips);
    e.u64(t.converged_epoch);
    e.u64(t.pin_depth);
    e.u64(t.delta_buckets);
    e.u64(t.decisions_owned);
    e.u64(t.decisions_delta);
    e.u64(t.induce_pass);
    e.u64(t.induce_block);
    e.u64(t.steal_rate_ppm);
    e.u64(t.admission_capacity);
    e.u64(t.queue_capacity);
}

fn decode_class(d: &mut Dec<'_>) -> Result<ClassStats, WireError> {
    Ok(ClassStats {
        jobs: d.u64()?,
        steals: d.u64()?,
        tree_nodes: d.u64()?,
        delta_children: d.u64()?,
        undo_pops: d.u64()?,
        materializations: d.u64()?,
        memo_lookups: d.u64()?,
        memo_hits: d.u64()?,
    })
}

fn decode_stats(d: &mut Dec<'_>) -> Result<ServiceStats, WireError> {
    let pool = PoolStats {
        pushes: d.u64()?,
        injected: d.u64()?,
        pops: d.u64()?,
        shared_pops: d.u64()?,
        steals: d.u64()?,
        steal_retries: d.u64()?,
        parks: d.u64()?,
        backlog: d.u64()? as usize,
    };
    let admission = AdmissionStats {
        queued: d.u64()? as usize,
        live_jobs: d.u64()? as usize,
        rejected: d.u64()?,
        quota_rejected: d.u64()?,
        blocked: Duration::from_nanos(d.u64()?),
        dispatched_latency: d.u64()?,
        dispatched_throughput: d.u64()?,
        live_bytes: d.u64()?,
        mem_rejected: d.u64()?,
        retries: d.u64()?,
        recovered: d.u64()?,
        quarantined: d.u64()?,
    };
    let mvc = decode_class(d)?;
    let pvc = decode_class(d)?;
    let mis = decode_class(d)?;
    let memo = MemoStats {
        lookups: d.u64()?,
        hits: d.u64()?,
        misses: d.u64()?,
        inserts: d.u64()?,
        evictions: d.u64()?,
        bytes: d.u64()?,
        saved_nodes: d.u64()?,
    };
    let autotune = AutotuneStats {
        enabled: d.u64()? != 0,
        epochs: d.u64()?,
        flips: d.u64()?,
        converged_epoch: d.u64()?,
        pin_depth: d.u64()?,
        delta_buckets: d.u64()?,
        decisions_owned: d.u64()?,
        decisions_delta: d.u64()?,
        induce_pass: d.u64()?,
        induce_block: d.u64()?,
        steal_rate_ppm: d.u64()?,
        admission_capacity: d.u64()?,
        queue_capacity: d.u64()?,
    };
    Ok(ServiceStats { pool, admission, mvc, pvc, mis, memo, autotune })
}

// ---------------------------------------------------------------------------
// Frame-level API
// ---------------------------------------------------------------------------

/// Encode one frame to its full wire representation (length prefix
/// included), ready for `write_all`.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut e = Enc::new();
    match frame {
        Frame::Hello { magic, version } => {
            e.u8(kind::HELLO);
            e.u32(*magic);
            e.u16(*version);
        }
        Frame::HelloAck { version } => {
            e.u8(kind::HELLO_ACK);
            e.u16(*version);
        }
        Frame::Submit(req) => {
            e.u8(kind::SUBMIT);
            e.u64(req.req_id);
            encode_problem(&mut e, &req.problem);
            encode_options(&mut e, &req.opts);
        }
        Frame::Solution(sol) => {
            e.u8(kind::SOLUTION);
            encode_solution(&mut e, sol);
        }
        Frame::Error(err) => {
            e.u8(kind::ERROR);
            e.u64(err.req_id);
            e.u8(err.code.as_u8());
            e.str(&err.detail);
        }
        Frame::Cancel { req_id } => {
            e.u8(kind::CANCEL);
            e.u64(*req_id);
        }
        Frame::StatsRequest => {
            e.u8(kind::STATS_REQUEST);
        }
        Frame::StatsReply(stats) => {
            e.u8(kind::STATS_REPLY);
            encode_stats(&mut e, stats);
        }
    }
    e.finish()
}

/// Decode the body of one frame (the bytes *after* the length prefix:
/// kind byte + payload). Checked end to end; trailing bytes are an
/// error so stream desyncs surface immediately instead of corrupting
/// the next field.
pub fn decode_payload(body: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec::new(body);
    let frame = match d.u8()? {
        kind::HELLO => {
            let magic = d.u32()?;
            let version = d.u16()?;
            if magic != WIRE_MAGIC {
                return Err(WireError::BadMagic(magic));
            }
            if version == 0 {
                return Err(WireError::Version(version));
            }
            Frame::Hello { magic, version }
        }
        kind::HELLO_ACK => Frame::HelloAck { version: d.u16()? },
        kind::SUBMIT => {
            let req_id = d.u64()?;
            if req_id == 0 {
                return Err(WireError::Malformed("request id 0 is reserved"));
            }
            let problem = decode_problem(&mut d)?;
            let opts = decode_options(&mut d)?;
            Frame::Submit(SubmitRequest { req_id, problem, opts })
        }
        kind::SOLUTION => Frame::Solution(Box::new(decode_solution(&mut d)?)),
        kind::ERROR => {
            let req_id = d.u64()?;
            let code =
                ErrorCode::from_u8(d.u8()?).ok_or(WireError::Malformed("error code"))?;
            let detail = d.str()?;
            Frame::Error(WireErrorFrame { req_id, code, detail })
        }
        kind::CANCEL => Frame::Cancel { req_id: d.u64()? },
        kind::STATS_REQUEST => Frame::StatsRequest,
        kind::STATS_REPLY => Frame::StatsReply(Box::new(decode_stats(&mut d)?)),
        k => return Err(WireError::UnknownKind(k)),
    };
    d.done()?;
    Ok(frame)
}

/// Blocking read of one frame from a stream. Length-prefix violations
/// (`len == 0`, `len > MAX_FRAME_LEN`) are caught before the payload is
/// allocated or consumed.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf);
    read_body(r, len)
}

/// Read a frame's body once its length prefix is known (the server's
/// idle-poll loop reads the prefix itself so it can distinguish "no
/// traffic" from "slow frame").
pub fn read_body<R: Read>(r: &mut R, len: u32) -> Result<Frame, WireError> {
    if len == 0 {
        return Err(WireError::Malformed("zero-length frame"));
    }
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode_payload(&body)
}

/// Write one frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode_frame(frame))?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_frame(f);
        let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
        assert_eq!(len + 4, bytes.len());
        decode_payload(&bytes[4..]).expect("roundtrip decode")
    }

    #[test]
    fn handshake_frames_roundtrip() {
        match roundtrip(&Frame::Hello { magic: WIRE_MAGIC, version: PROTOCOL_VERSION }) {
            Frame::Hello { magic, version } => {
                assert_eq!(magic, WIRE_MAGIC);
                assert_eq!(version, PROTOCOL_VERSION);
            }
            f => panic!("wrong frame {f:?}"),
        }
        match roundtrip(&Frame::HelloAck { version: 1 }) {
            Frame::HelloAck { version } => assert_eq!(version, 1),
            f => panic!("wrong frame {f:?}"),
        }
    }

    #[test]
    fn submit_roundtrips_graph_options_and_k() {
        let g = generators::erdos_renyi(40, 0.15, 7);
        let (nv, ne) = (g.num_vertices(), g.num_edges());
        let req = SubmitRequest {
            req_id: 99,
            problem: Problem::pvc(g, 17),
            opts: WireOptions {
                lane: Some(Lane::Latency),
                timeout: Some(Duration::from_millis(1500)),
                tenant: Some("acme".into()),
                extract_witness: true,
                memo: Some(false),
            },
        };
        match roundtrip(&Frame::Submit(req)) {
            Frame::Submit(r) => {
                assert_eq!(r.req_id, 99);
                assert!(matches!(r.problem, Problem::Pvc { k: 17, .. }));
                assert_eq!(r.problem.graph().num_vertices(), nv);
                assert_eq!(r.problem.graph().num_edges(), ne);
                assert_eq!(r.opts.lane, Some(Lane::Latency));
                assert_eq!(r.opts.timeout, Some(Duration::from_millis(1500)));
                assert_eq!(r.opts.tenant.as_deref(), Some("acme"));
                assert!(r.opts.extract_witness);
                assert_eq!(r.opts.memo, Some(false));
            }
            f => panic!("wrong frame {f:?}"),
        }
    }

    #[test]
    fn solution_and_error_frames_roundtrip() {
        let sol = WireSolution {
            req_id: 3,
            problem: ProblemKind::Mvc,
            objective: 12,
            feasible: true,
            witness: Some(vec![1, 4, 9]),
            witness_verified: Some(true),
            termination: Termination::Complete,
            failure: None,
            elapsed: Duration::from_micros(1234),
            tree_nodes: 100,
            component_branches: 5,
            induced_subproblems: 2,
            memo_lookups: 4,
            memo_hits: 1,
            n_residual: 30,
            forced: 3,
            greedy_ub: 15,
        };
        match roundtrip(&Frame::Solution(Box::new(sol))) {
            Frame::Solution(s) => {
                assert_eq!(s.req_id, 3);
                assert_eq!(s.objective, 12);
                assert_eq!(s.witness.as_deref(), Some(&[1u32, 4, 9][..]));
                assert_eq!(s.witness_verified, Some(true));
                assert_eq!(s.termination, Termination::Complete);
                assert_eq!(s.elapsed, Duration::from_micros(1234));
                assert_eq!(s.greedy_ub, 15);
            }
            f => panic!("wrong frame {f:?}"),
        }
        let err = WireErrorFrame {
            req_id: 0,
            code: ErrorCode::QuotaExceeded,
            detail: "tenant quota exceeded".into(),
        };
        match roundtrip(&Frame::Error(err)) {
            Frame::Error(e) => {
                assert_eq!(e.req_id, 0);
                assert_eq!(e.code, ErrorCode::QuotaExceeded);
                assert_eq!(e.code.submit_error(), Some(SubmitError::QuotaExceeded));
            }
            f => panic!("wrong frame {f:?}"),
        }
    }

    #[test]
    fn stats_reply_roundtrips_every_counter() {
        let s = ServiceStats {
            pool: PoolStats { pushes: 11, backlog: 3, ..PoolStats::default() },
            admission: AdmissionStats {
                queued: 2,
                live_jobs: 5,
                blocked: Duration::from_nanos(777),
                quota_rejected: 9,
                ..AdmissionStats::default()
            },
            mvc: ClassStats { jobs: 4, ..ClassStats::default() },
            pvc: ClassStats { tree_nodes: 123, ..ClassStats::default() },
            mis: ClassStats { memo_hits: 8, ..ClassStats::default() },
            memo: MemoStats { bytes: 4096, ..MemoStats::default() },
            autotune: AutotuneStats {
                enabled: true,
                epochs: 40,
                flips: 6,
                converged_epoch: 31,
                pin_depth: 28,
                delta_buckets: 0b1111_1000,
                decisions_owned: 100,
                decisions_delta: 200,
                induce_pass: 77,
                induce_block: 3,
                steal_rate_ppm: 52_000,
                admission_capacity: 2048,
                queue_capacity: 512,
            },
        };
        match roundtrip(&Frame::StatsReply(Box::new(s))) {
            Frame::StatsReply(r) => {
                assert_eq!(r.pool.pushes, 11);
                assert_eq!(r.pool.backlog, 3);
                assert_eq!(r.admission.queued, 2);
                assert_eq!(r.admission.live_jobs, 5);
                assert_eq!(r.admission.blocked, Duration::from_nanos(777));
                assert_eq!(r.admission.quota_rejected, 9);
                assert_eq!(r.mvc.jobs, 4);
                assert_eq!(r.pvc.tree_nodes, 123);
                assert_eq!(r.mis.memo_hits, 8);
                assert_eq!(r.memo.bytes, 4096);
                assert!(r.autotune.enabled);
                assert_eq!(r.autotune.epochs, 40);
                assert_eq!(r.autotune.flips, 6);
                assert_eq!(r.autotune.converged_epoch, 31);
                assert_eq!(r.autotune.pin_depth, 28);
                assert_eq!(r.autotune.delta_buckets, 0b1111_1000);
                assert_eq!(r.autotune.decisions_owned, 100);
                assert_eq!(r.autotune.decisions_delta, 200);
                assert_eq!(r.autotune.induce_pass, 77);
                assert_eq!(r.autotune.induce_block, 3);
                assert_eq!(r.autotune.steal_rate_ppm, 52_000);
                assert_eq!(r.autotune.admission_capacity, 2048);
                assert_eq!(r.autotune.queue_capacity, 512);
            }
            f => panic!("wrong frame {f:?}"),
        }
    }

    #[test]
    fn malformed_payloads_error_not_panic() {
        // Unknown kind.
        assert!(matches!(decode_payload(&[0xEE]), Err(WireError::UnknownKind(0xEE))));
        // Truncated submit.
        assert!(matches!(decode_payload(&[kind::SUBMIT, 1, 2]), Err(WireError::Truncated)));
        // Trailing garbage after a complete frame.
        let mut bytes = encode_frame(&Frame::StatsRequest);
        bytes.push(0xAB);
        assert!(matches!(decode_payload(&bytes[4..]), Err(WireError::Trailing(1))));
        // Bad magic.
        let hello = encode_frame(&Frame::Hello { magic: 0xDEAD_BEEF, version: 1 });
        assert!(matches!(decode_payload(&hello[4..]), Err(WireError::BadMagic(0xDEAD_BEEF))));
        // Asymmetric CSR: row 0 lists neighbor 1, row 1 is empty.
        let mut e = Enc::new();
        e.u8(kind::SUBMIT);
        e.u64(1);
        e.u8(0); // Mvc
        e.u32(0); // k
        e.u32(2); // n
        e.u32(0);
        e.u32(1);
        e.u32(1); // row_ptr = [0, 1, 1]
        e.u32(1); // adj = [1]
        e.u8(0); // options flags
        let bytes = e.finish();
        assert!(matches!(
            decode_payload(&bytes[4..]),
            Err(WireError::Malformed("asymmetric edge"))
        ));
    }

    #[test]
    fn oversized_and_empty_lengths_rejected_before_allocation() {
        let mut buf: &[u8] = &(MAX_FRAME_LEN + 1).to_le_bytes();
        assert!(matches!(read_frame(&mut buf), Err(WireError::Oversized(_))));
        let mut buf: &[u8] = &0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut buf), Err(WireError::Malformed(_))));
    }
}
