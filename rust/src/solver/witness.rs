//! Witness verification and lifting — the trust layer for extracted
//! covers.
//!
//! Every solver path that extracts a witness (the sequential baseline,
//! the parallel engine's choice logs, the brute-force oracle, the greedy
//! fallback) funnels through this module: [`verify_cover`] /
//! [`verify_independent_set`] check a claimed solution vertex-by-vertex
//! against the *original* graph and report the first offending edge on
//! failure, and [`CoverLift`] carries the two translation layers a
//! residual-relative witness must cross on its way back to original
//! vertex ids — the root-induction renumbering
//! ([`crate::graph::InducedSubgraph`]) and the prep-phase reduction
//! unwinding ([`crate::reduce::UnwindLog`]).
//!
//! Used by the differential witness fuzz suite, the CLI's `--check`
//! flag, and the service's `witness_verified` stat.

use crate::graph::Graph;
use crate::reduce::UnwindLog;

/// Why a claimed witness is not a valid solution. Carries the first
/// offending vertex/edge so failures are directly actionable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WitnessError {
    /// An edge `(u, v)` has neither endpoint in the claimed cover.
    UncoveredEdge {
        /// One endpoint of the uncovered edge.
        u: u32,
        /// The other endpoint.
        v: u32,
    },
    /// Two vertices of the claimed independent set are adjacent.
    AdjacentPair {
        /// One endpoint of the internal edge.
        u: u32,
        /// The other endpoint.
        v: u32,
    },
    /// A witness vertex is out of the graph's vertex range.
    OutOfRange {
        /// The offending vertex id.
        v: u32,
        /// The graph's vertex count.
        n: usize,
    },
    /// A vertex appears more than once in the witness.
    Duplicate {
        /// The repeated vertex id.
        v: u32,
    },
}

impl std::fmt::Display for WitnessError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WitnessError::UncoveredEdge { u, v } => {
                write!(f, "edge ({u}, {v}) is not covered by the witness")
            }
            WitnessError::AdjacentPair { u, v } => {
                write!(f, "witness vertices {u} and {v} are adjacent")
            }
            WitnessError::OutOfRange { v, n } => {
                write!(f, "witness vertex {v} out of range (|V| = {n})")
            }
            WitnessError::Duplicate { v } => write!(f, "witness vertex {v} repeated"),
        }
    }
}

impl std::error::Error for WitnessError {}

/// Check membership bookkeeping shared by both verifiers: bounds,
/// duplicates, and the membership mask.
fn membership(g: &Graph, set: &[u32]) -> Result<Vec<bool>, WitnessError> {
    let n = g.num_vertices();
    let mut inset = vec![false; n];
    for &v in set {
        if v as usize >= n {
            return Err(WitnessError::OutOfRange { v, n });
        }
        if inset[v as usize] {
            return Err(WitnessError::Duplicate { v });
        }
        inset[v as usize] = true;
    }
    Ok(inset)
}

/// Verify that `cover` is a vertex cover of `g`: every edge has at least
/// one endpoint in it. Reports the first uncovered edge on failure (plus
/// range/duplicate defects, which would make size comparisons lie).
pub fn verify_cover(g: &Graph, cover: &[u32]) -> Result<(), WitnessError> {
    let inset = membership(g, cover)?;
    for (u, v) in g.edges() {
        if !inset[u as usize] && !inset[v as usize] {
            return Err(WitnessError::UncoveredEdge { u, v });
        }
    }
    Ok(())
}

/// Verify that `set` is an independent set of `g`: no edge joins two of
/// its vertices. Reports the first internal edge on failure.
pub fn verify_independent_set(g: &Graph, set: &[u32]) -> Result<(), WitnessError> {
    let inset = membership(g, set)?;
    for (u, v) in g.edges() {
        if inset[u as usize] && inset[v as usize] {
            return Err(WitnessError::AdjacentPair { u, v });
        }
    }
    Ok(())
}

/// Pick the MVC witness of record for a reported best: the engine's
/// assembled (already lifted) cover when it accounts for every vertex of
/// `best`, else the greedy cover when `best` is the greedy bound —
/// shared by the one-shot pipeline and the service's finalization so the
/// two paths can never drift.
pub fn cover_of_record(
    lifted: Option<Vec<u32>>,
    best: u32,
    greedy_ub: u32,
    g: &Graph,
) -> Option<Vec<u32>> {
    lifted
        .filter(|c| c.len() as u32 == best)
        .or_else(|| (best == greedy_ub).then(|| crate::solver::greedy::greedy_cover(g)))
}

/// The complement of a vertex set — lifts an MVC witness to the MIS
/// witness (`α(G) = |V| − MVC(G)` duals share one extraction path).
pub fn complement(g: &Graph, set: &[u32]) -> Vec<u32> {
    let mut inset = vec![false; g.num_vertices()];
    for &v in set {
        inset[v as usize] = true;
    }
    (0..g.num_vertices() as u32).filter(|&v| !inset[v as usize]).collect()
}

/// The lift from a residual-relative witness to original vertex ids:
/// translate through the root-induction renumbering, then unwind the
/// prep-phase reductions so every root-forced vertex's cover decision is
/// restored. Owns its maps so the service can keep it after the
/// preparation stage's graphs are gone.
#[derive(Debug, Clone, Default)]
pub struct CoverLift {
    /// residual id → original id (the induction's `to_original` map).
    to_original: Vec<u32>,
    /// Root-reduction decision log, replayed in reverse.
    unwind: UnwindLog,
}

impl CoverLift {
    /// Build a lift from the induction map and the reduction log.
    pub fn new(to_original: Vec<u32>, unwind: UnwindLog) -> CoverLift {
        CoverLift { to_original, unwind }
    }

    /// Number of vertices the unwind appends on top of any residual
    /// cover (the root-forced cover size).
    pub fn forced_count(&self) -> usize {
        self.unwind.covered_count()
    }

    /// Lift `residual_cover` (ids over the residual graph) to a cover of
    /// the original graph.
    pub fn lift(&self, residual_cover: &[u32]) -> Vec<u32> {
        let mut cover: Vec<u32> =
            residual_cover.iter().map(|&v| self.to_original[v as usize]).collect();
        self.unwind.unwind(&mut cover);
        cover
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn valid_cover_accepted() {
        let g = generators::path(5); // 0-1-2-3-4
        assert_eq!(verify_cover(&g, &[1, 3]), Ok(()));
        assert_eq!(verify_cover(&g, &[0, 1, 2, 3, 4]), Ok(()));
    }

    #[test]
    fn first_uncovered_edge_reported() {
        let g = generators::path(5);
        assert_eq!(verify_cover(&g, &[1]), Err(WitnessError::UncoveredEdge { u: 2, v: 3 }));
        assert_eq!(verify_cover(&g, &[]), Err(WitnessError::UncoveredEdge { u: 0, v: 1 }));
    }

    #[test]
    fn range_and_duplicates_rejected() {
        let g = generators::path(3);
        assert_eq!(verify_cover(&g, &[7]), Err(WitnessError::OutOfRange { v: 7, n: 3 }));
        assert_eq!(verify_cover(&g, &[1, 1]), Err(WitnessError::Duplicate { v: 1 }));
    }

    #[test]
    fn independent_set_checked() {
        let g = generators::path(4);
        assert_eq!(verify_independent_set(&g, &[0, 2]), Ok(()));
        assert_eq!(
            verify_independent_set(&g, &[0, 1]),
            Err(WitnessError::AdjacentPair { u: 0, v: 1 })
        );
        assert_eq!(verify_independent_set(&g, &[]), Ok(()));
    }

    #[test]
    fn complement_of_cover_is_independent() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(14, 0.25, seed);
            let cover = crate::solver::oracle::mvc_cover(&g);
            let mis = complement(&g, &cover);
            assert_eq!(verify_independent_set(&g, &mis), Ok(()), "seed {seed}");
            assert_eq!(mis.len(), g.num_vertices() - cover.len(), "seed {seed}");
        }
    }

    #[test]
    fn lift_composes_translation_and_unwind() {
        // P5 reduces fully at the root: the lift of the empty residual
        // cover must be the forced cover itself.
        let g = generators::path(5);
        let p = crate::prep::prepare(&g, &crate::prep::PrepConfig::default(), None);
        let lift = p.cover_lift();
        let cover = lift.lift(&[]);
        assert_eq!(cover.len(), lift.forced_count());
        assert_eq!(verify_cover(&g, &cover), Ok(()));
    }

    #[test]
    fn error_messages_name_the_edge() {
        let e = WitnessError::UncoveredEdge { u: 3, v: 9 };
        assert!(e.to_string().contains("(3, 9)"));
    }
}
