//! Shared load-balancing worklist (paper §II-C).
//!
//! The state-of-the-art GPU solution offloads search-tree nodes from busy
//! thread blocks to idle ones through a multi-producer multi-consumer
//! broker queue. Here: mutex-sharded FIFO deques with an approximate
//! global length counter. A worker pushes to its home shard and steals
//! round-robin from the others; the length counter implements the
//! "is the worklist hungry?" offload heuristic without taking locks.
//!
//! This is the backing store of the baseline
//! [`crate::solver::sched::ShardedScheduler`]; the engine's default
//! runtime is the lock-free work stealer in [`crate::solver::sched`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Sharded MPMC worklist.
#[derive(Debug)]
pub struct Worklist<T> {
    shards: Vec<Mutex<VecDeque<T>>>,
    len: AtomicUsize,
    pushes: AtomicUsize,
    steals: AtomicUsize,
}

impl<T> Worklist<T> {
    /// Create a worklist with one shard per `shards` (≥ 1).
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        Worklist {
            shards: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            len: AtomicUsize::new(0),
            pushes: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        }
    }

    /// Approximate number of queued items.
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// True if no items are queued (approximate).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Offload heuristic: the worklist wants more work if it holds fewer
    /// than `low_water` items.
    #[inline]
    pub fn is_hungry(&self, low_water: usize) -> bool {
        self.len() < low_water
    }

    /// Push an item to the `home` shard.
    pub fn push(&self, home: usize, item: T) {
        let shard = &self.shards[home % self.shards.len()];
        shard.lock().unwrap().push_back(item);
        self.len.fetch_add(1, Ordering::Relaxed);
        self.pushes.fetch_add(1, Ordering::Relaxed);
    }

    /// Pop, scanning shards starting from `home` (so a worker drains its
    /// own shard before stealing).
    pub fn pop(&self, home: usize) -> Option<T> {
        self.pop_traced(home).map(|(item, _)| item)
    }

    /// Like [`Worklist::pop`], but also reports whether the item came
    /// from a foreign shard (a cross-worker steal) — the per-worker
    /// counter feed for the scheduler statistics.
    pub fn pop_traced(&self, home: usize) -> Option<(T, bool)> {
        if self.is_empty() {
            return None;
        }
        let k = self.shards.len();
        for i in 0..k {
            let shard = &self.shards[(home + i) % k];
            if let Some(item) = shard.lock().unwrap().pop_front() {
                self.len.fetch_sub(1, Ordering::Relaxed);
                if i > 0 {
                    self.steals.fetch_add(1, Ordering::Relaxed);
                }
                return Some((item, i > 0));
            }
        }
        None
    }

    /// Total pushes over the run (statistics).
    pub fn total_pushes(&self) -> usize {
        self.pushes.load(Ordering::Relaxed)
    }

    /// Total cross-shard steals over the run (statistics).
    pub fn total_steals(&self) -> usize {
        self.steals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_shard() {
        let w = Worklist::new(1);
        w.push(0, 1);
        w.push(0, 2);
        w.push(0, 3);
        assert_eq!(w.pop(0), Some(1));
        assert_eq!(w.pop(0), Some(2));
        assert_eq!(w.pop(0), Some(3));
        assert_eq!(w.pop(0), None);
    }

    #[test]
    fn steals_across_shards() {
        let w = Worklist::new(4);
        w.push(2, 42);
        assert_eq!(w.pop(0), Some(42));
        assert_eq!(w.total_steals(), 1);
    }

    /// Items move by value, so owned buffers (e.g. a node's witness
    /// choice log) survive a cross-shard steal intact — the thief owns
    /// the log, no aliasing with the victim.
    #[test]
    fn stolen_items_own_their_buffers() {
        struct Item {
            log: Vec<u32>,
        }
        let w = Worklist::new(3);
        w.push(1, Item { log: vec![7, 8, 9] });
        let (stolen, foreign) = w.pop_traced(0).expect("item present");
        assert!(foreign, "pop from shard 0 must steal shard 1's item");
        assert_eq!(stolen.log, vec![7, 8, 9]);
        let mut log = stolen.log;
        log.push(10); // the thief extends its own copy freely
        assert_eq!(log.len(), 4);
        assert!(w.is_empty());
    }

    #[test]
    fn hungry_threshold() {
        let w = Worklist::new(2);
        assert!(w.is_hungry(1));
        w.push(0, 1);
        assert!(!w.is_hungry(1));
        assert!(w.is_hungry(5));
    }

    #[test]
    fn concurrent_push_pop_conserves_items() {
        let w = Arc::new(Worklist::new(8));
        let n_threads = 8;
        let per = 5_000usize;
        let popped = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let w = Arc::clone(&w);
                s.spawn(move || {
                    for i in 0..per {
                        w.push(t, (t, i));
                    }
                });
            }
            for t in 0..n_threads {
                let w = Arc::clone(&w);
                let popped = Arc::clone(&popped);
                s.spawn(move || loop {
                    if w.pop(t).is_some() {
                        let c = popped.fetch_add(1, Ordering::Relaxed) + 1;
                        if c == n_threads * per {
                            break;
                        }
                    } else if popped.load(Ordering::Relaxed) == n_threads * per {
                        break;
                    } else {
                        std::thread::yield_now();
                    }
                });
            }
        });
        assert_eq!(popped.load(Ordering::Relaxed), n_threads * per);
        assert!(w.is_empty());
    }
}
