//! Fixed-capacity bitset used by BFS visitation marks, crown reduction,
//! and induced-subgraph construction.

/// A fixed-size bitset over `u64` words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// Create a bitset with `len` bits, all clear.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the bitset has zero capacity.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1 << (i & 63);
    }

    /// Clear bit `i`.
    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1 << (i & 63));
    }

    /// Test bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i` and report whether it was previously clear.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        let was = self.get(i);
        self.set(i);
        !was
    }

    /// Clear all bits.
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over indices of set bits in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some((wi << 6) + b)
                }
            })
        })
    }

    /// Index of the first clear bit below `self.len()`, if any.
    pub fn first_zero(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != u64::MAX {
                let b = (!w).trailing_zeros() as usize;
                let idx = (wi << 6) + b;
                if idx < self.len {
                    return Some(idx);
                }
            }
        }
        None
    }

    /// In-place union with another bitset of the same length.
    pub fn union_with(&mut self, other: &BitSet) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(130);
        assert!(!b.get(0));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(63) && !b.get(128));
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn insert_reports_new() {
        let mut b = BitSet::new(10);
        assert!(b.insert(3));
        assert!(!b.insert(3));
    }

    #[test]
    fn iter_ones_order() {
        let mut b = BitSet::new(200);
        for &i in &[5usize, 63, 64, 127, 128, 199] {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, vec![5, 63, 64, 127, 128, 199]);
    }

    #[test]
    fn first_zero_skips_full_words() {
        let mut b = BitSet::new(130);
        for i in 0..100 {
            b.set(i);
        }
        assert_eq!(b.first_zero(), Some(100));
        for i in 100..130 {
            b.set(i);
        }
        assert_eq!(b.first_zero(), None);
    }

    #[test]
    fn union() {
        let mut a = BitSet::new(70);
        let mut b = BitSet::new(70);
        a.set(1);
        b.set(69);
        a.union_with(&b);
        assert!(a.get(1) && a.get(69));
    }
}
