//! A tiny argument parser (the build is offline; no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, in any order. Unknown flags are an error so typos fail fast.

use std::collections::HashMap;

/// Parsed command line: positionals plus key/value options.
#[derive(Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `valued` lists option names that consume a
    /// value; anything else starting with `--` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        valued: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if valued.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Positional argument `i`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// All positionals.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Positional arguments from index `i` on — the tail a batch verb
    /// treats as "one job per argument" (`cavc solve --jobs list.txt
    /// extra.gr ...`).
    pub fn pos_rest(&self, i: usize) -> &[String] {
        self.positional.get(i..).unwrap_or(&[])
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag presence.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    /// Typed option with default; errors if present but unparsable.
    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{key}: cannot parse {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(
            v(&["solve", "--workers", "8", "--timeout=30", "--verbose", "g.mtx"]),
            &["workers", "timeout"],
        )
        .unwrap();
        assert_eq!(a.pos(0), Some("solve"));
        assert_eq!(a.pos(1), Some("g.mtx"));
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get_parse::<f64>("timeout", 0.0).unwrap(), 30.0);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(v(&["--workers"]), &["workers"]).is_err());
    }

    #[test]
    fn parse_error_reported() {
        let a = Args::parse(v(&["--k=abc"]), &[]).unwrap();
        assert!(a.get_parse::<u32>("k", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(v(&[]), &[]).unwrap();
        assert_eq!(a.get_parse::<u32>("k", 7).unwrap(), 7);
    }

    #[test]
    fn pos_rest_returns_tail() {
        let a = Args::parse(v(&["solve", "a.gr", "b.gr", "c.gr"]), &[]).unwrap();
        assert_eq!(a.pos_rest(1), &["a.gr".to_string(), "b.gr".into(), "c.gr".into()]);
        assert_eq!(a.pos_rest(3), &["c.gr".to_string()]);
        assert!(a.pos_rest(4).is_empty());
        assert!(a.pos_rest(99).is_empty());
    }
}
