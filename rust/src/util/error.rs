//! Minimal error type with context chaining (the build is fully offline,
//! so this replaces the usual `anyhow` dependency).
//!
//! Provides the small subset the codebase needs: a string-backed
//! [`Error`], a [`Result`] alias defaulting the error type, a [`Context`]
//! extension trait for `Result`/`Option`, and the [`bail!`] / [`ensure!`]
//! macros. Context layers render as `outer: inner`, matching how
//! `anyhow`'s alternate formatting (`{e:#}`) prints chains.

use std::fmt;

/// A boxed, human-readable error with optional context layers.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap this error with an outer context layer.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(e: String) -> Error {
        Error { msg: e }
    }
}

impl From<&str> for Error {
    fn from(e: &str) -> Error {
        Error::msg(e)
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` / `Option` failures.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

pub use crate::{bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_chains() {
        let r: Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
    }

    #[test]
    fn io_error_converts() {
        fn open() -> Result<std::fs::File> {
            Ok(std::fs::File::open("/definitely/not/here")?)
        }
        assert!(open().is_err());
    }
}
