//! Small self-contained utilities (the build is fully offline, so these
//! replace the usual `rand` / `fixedbitset` / `clap` / `anyhow`
//! dependencies).

pub mod bitset;
pub mod cli;
pub mod error;
pub mod rng;
pub mod timer;

pub use bitset::BitSet;
pub use rng::SplitMix64;
pub use timer::ActivityTimer;

/// Format a duration in seconds the way the paper's tables do: seconds
/// with millisecond precision, or `>Xhrs` when the run timed out.
pub fn fmt_secs(secs: f64, timed_out: bool, timeout_secs: f64) -> String {
    if timed_out {
        if timeout_secs >= 3600.0 {
            format!(">{:.0}hrs", timeout_secs / 3600.0)
        } else {
            format!(">{:.0}s", timeout_secs)
        }
    } else if secs >= 3600.0 {
        format!("{:.3}hrs", secs / 3600.0)
    } else {
        format!("{:.3}", secs)
    }
}

/// Format a speedup ratio like the paper: `12.8x`, or `>732.8x` when the
/// baseline timed out (lower bound).
pub fn fmt_speedup(baseline: f64, ours: f64, baseline_timed_out: bool) -> String {
    if ours <= 0.0 {
        return "-".to_string();
    }
    let ratio = baseline / ours;
    let pretty = if ratio >= 100.0 {
        format!("{:.0}x", ratio)
    } else if ratio >= 10.0 {
        format!("{:.1}x", ratio)
    } else {
        format!("{:.2}x", ratio)
    };
    if baseline_timed_out {
        format!(">{pretty}")
    } else {
        pretty
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_basic() {
        assert_eq!(fmt_secs(0.00731, false, 30.0), "0.007");
        assert_eq!(fmt_secs(2.147, false, 30.0), "2.147");
    }

    #[test]
    fn fmt_secs_timeout() {
        assert_eq!(fmt_secs(21600.0, true, 21600.0), ">6hrs");
        assert_eq!(fmt_secs(30.0, true, 30.0), ">30s");
    }

    #[test]
    fn fmt_secs_hours() {
        assert_eq!(fmt_secs(5.628 * 3600.0, false, 21600.0), "5.628hrs");
    }

    #[test]
    fn fmt_speedup_bands() {
        assert_eq!(fmt_speedup(0.131, 0.066, false), "1.98x");
        assert_eq!(fmt_speedup(70.5, 30.6, false), "2.30x");
        assert_eq!(fmt_speedup(21600.0, 29.475, true), ">733x");
        assert_eq!(fmt_speedup(1000.0, 1.0, false), "1000x");
    }
}
