//! Deterministic pseudo-random number generation.
//!
//! All synthetic datasets and property tests in this repo are seeded, so
//! every experiment is exactly reproducible. SplitMix64 is small, fast,
//! and passes BigCrush for our purposes (dataset generation, shuffles).

/// SplitMix64 generator (Steele, Lea & Flood; the JDK `SplittableRandom`
/// mixing function).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.index(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.index(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn bounded_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_below(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_roughly_uniform() {
        let mut r = SplitMix64::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.index(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = SplitMix64::new(11);
        let s = r.sample_distinct(50, 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&x| x < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
