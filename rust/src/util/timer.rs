//! Per-worker activity timing for the Figure-4 execution breakdown.
//!
//! The paper instruments CUDA thread blocks with SM clocks, counts cycles
//! per activity, normalizes per block, and averages across blocks. We do
//! the same with monotonic clocks per worker thread: each worker owns an
//! [`ActivityTimer`], charges elapsed time to one [`Activity`] at a time,
//! and the harness merges + normalizes the per-worker totals.

use std::time::Instant;

/// Activities charged by the solver engine, matching Figure 4's legend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Activity {
    /// Applying reduction rules (incl. root reduce + induce on worker 0).
    Reduce = 0,
    /// BFS component search + registry updates.
    ComponentSearch = 1,
    /// Selecting the branch vertex and materializing children.
    Branch = 2,
    /// Private stack and shared worklist access (push/pop/steal).
    Queue = 3,
    /// Stopping-condition checks and leaf handling.
    Leaf = 4,
    /// Waiting while idle (excluded from the normalized breakdown, the
    /// paper reports busy-time proportions).
    Idle = 5,
}

/// Number of activity classes.
pub const NUM_ACTIVITIES: usize = 6;

/// All activities in display order.
pub const ALL_ACTIVITIES: [Activity; NUM_ACTIVITIES] = [
    Activity::Reduce,
    Activity::ComponentSearch,
    Activity::Branch,
    Activity::Queue,
    Activity::Leaf,
    Activity::Idle,
];

impl Activity {
    /// Human-readable label as used in Figure 4.
    pub fn label(self) -> &'static str {
        match self {
            Activity::Reduce => "reduction rules",
            Activity::ComponentSearch => "components search",
            Activity::Branch => "branching",
            Activity::Queue => "stack/worklist",
            Activity::Leaf => "stopping/leaf",
            Activity::Idle => "idle",
        }
    }
}

/// Accumulates nanoseconds per activity for one worker.
#[derive(Debug, Clone)]
pub struct ActivityTimer {
    nanos: [u64; NUM_ACTIVITIES],
    current: Option<(Activity, Instant)>,
    enabled: bool,
}

impl Default for ActivityTimer {
    fn default() -> Self {
        Self::disabled()
    }
}

impl ActivityTimer {
    /// A timer that records.
    pub fn enabled() -> Self {
        Self { nanos: [0; NUM_ACTIVITIES], current: None, enabled: true }
    }

    /// A timer that is a no-op (zero overhead on the hot path).
    pub fn disabled() -> Self {
        Self { nanos: [0; NUM_ACTIVITIES], current: None, enabled: false }
    }

    /// Switch the charged activity, closing out the previous one.
    #[inline]
    pub fn switch(&mut self, act: Activity) {
        if !self.enabled {
            return;
        }
        let now = Instant::now();
        if let Some((prev, start)) = self.current.take() {
            self.nanos[prev as usize] += now.duration_since(start).as_nanos() as u64;
        }
        self.current = Some((act, now));
    }

    /// Stop charging (e.g. at worker exit).
    pub fn stop(&mut self) {
        if !self.enabled {
            return;
        }
        if let Some((prev, start)) = self.current.take() {
            self.nanos[prev as usize] += start.elapsed().as_nanos() as u64;
        }
    }

    /// Raw nanosecond totals.
    pub fn totals(&self) -> [u64; NUM_ACTIVITIES] {
        self.nanos
    }

    /// Merge another worker's totals into this one.
    pub fn merge(&mut self, other: &ActivityTimer) {
        for i in 0..NUM_ACTIVITIES {
            self.nanos[i] += other.nanos[i];
        }
    }

    /// Busy-time fractions per activity (idle excluded), summing to ~1.
    pub fn breakdown(&self) -> [f64; NUM_ACTIVITIES] {
        let busy: u64 = ALL_ACTIVITIES
            .iter()
            .filter(|a| **a != Activity::Idle)
            .map(|a| self.nanos[*a as usize])
            .sum();
        let mut out = [0.0; NUM_ACTIVITIES];
        if busy > 0 {
            for a in ALL_ACTIVITIES {
                if a != Activity::Idle {
                    out[a as usize] = self.nanos[a as usize] as f64 / busy as f64;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_noop() {
        let mut t = ActivityTimer::disabled();
        t.switch(Activity::Reduce);
        t.stop();
        assert_eq!(t.totals(), [0; NUM_ACTIVITIES]);
    }

    #[test]
    fn charges_elapsed_time() {
        let mut t = ActivityTimer::enabled();
        t.switch(Activity::Reduce);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.switch(Activity::Branch);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.stop();
        let n = t.totals();
        assert!(n[Activity::Reduce as usize] >= 1_000_000);
        assert!(n[Activity::Branch as usize] >= 500_000);
        assert_eq!(n[Activity::Idle as usize], 0);
    }

    #[test]
    fn breakdown_sums_to_one() {
        let mut t = ActivityTimer::enabled();
        t.switch(Activity::Reduce);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.switch(Activity::Idle);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.stop();
        let b = t.breakdown();
        let sum: f64 = b.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
        assert_eq!(b[Activity::Idle as usize], 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = ActivityTimer::enabled();
        a.switch(Activity::Queue);
        std::thread::sleep(std::time::Duration::from_millis(1));
        a.stop();
        let mut b = ActivityTimer::enabled();
        b.switch(Activity::Queue);
        std::thread::sleep(std::time::Duration::from_millis(1));
        b.stop();
        let before = a.totals()[Activity::Queue as usize];
        a.merge(&b);
        assert!(a.totals()[Activity::Queue as usize] > before);
    }
}
