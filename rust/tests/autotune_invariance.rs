//! Differential tests for the online self-tuning controller
//! ([`cavc::solver::autotune`]): every knob it turns — node
//! representation, pin depth, induction gating, pool shape — is a
//! performance lever, never a correctness lever, so a service with the
//! controller on must return the same objectives and (on serial runs)
//! bit-identical verified witnesses as one with it off. The watchdog's
//! soft-pressure forced-delta override must also outrank whatever the
//! controller decided.

use cavc::graph::generators;
use cavc::solver::engine::NodeRepr;
use cavc::solver::{
    oracle, JobHandle, JobOptions, Lane, Problem, SchedulerKind, Solution, SolverConfig,
    Termination, VcService,
};
use std::time::{Duration, Instant};

/// Component-rich workloads (the memo-suite shape): unions of small
/// random parts, so jobs split into several induced components and the
/// controller sees traffic in more than one width bucket.
fn workload() -> Vec<cavc::graph::Graph> {
    (0..6u64).map(|seed| generators::union_of_random(4, 4, 8, 0.35, seed)).collect()
}

fn extract_opts() -> JobOptions {
    JobOptions { extract_witness: true, ..JobOptions::default() }
}

/// Run the workload once through `svc`, returning (objective, witness)
/// per job after asserting completion and witness verification.
fn run_batch(svc: &VcService) -> Vec<(u32, Vec<u32>)> {
    let handles: Vec<_> = workload()
        .into_iter()
        .map(|g| svc.submit_with(Problem::mvc(g), extract_opts()))
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(i, h)| {
            let sol = h.wait();
            assert_eq!(sol.termination, Termination::Complete, "job {i}");
            assert_eq!(sol.witness_verified, Some(true), "job {i}: witness must verify");
            (sol.objective, sol.witness.expect("extracting job returns a witness"))
        })
        .collect()
}

/// Serial runs are bit-deterministic, so the controller must be fully
/// transparent: same objectives, same (sorted) witness arrays, across
/// both schedulers and both configured node representations, on both
/// cold and memo-warm passes.
#[test]
fn serial_answers_are_bit_identical_with_autotune_on_and_off() {
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        for repr in [NodeRepr::Owned, NodeRepr::Delta] {
            let cfg = SolverConfig::proposed().with_node_repr(repr);
            let on = VcService::builder()
                .config(cfg.clone())
                .scheduler(sched)
                .workers(1)
                .autotune(true)
                .build();
            let off = VcService::builder()
                .config(cfg)
                .scheduler(sched)
                .workers(1)
                .autotune(false)
                .build();
            let tag = format!("{}/{}", sched.name(), repr.name());
            // cold pass, then a memo-warm pass, on each service
            let on_cold = run_batch(&on);
            let on_warm = run_batch(&on);
            let off_cold = run_batch(&off);
            let off_warm = run_batch(&off);
            assert_eq!(on_cold, off_cold, "{tag}: cold answers diverge with autotune on");
            assert_eq!(on_warm, off_warm, "{tag}: warm answers diverge with autotune on");
            assert_eq!(on_cold, on_warm, "{tag}: warm pass diverges from cold (autotune on)");
            for (i, (g, (obj, _))) in workload().iter().zip(&on_cold).enumerate() {
                assert_eq!(*obj, oracle::mvc_size(g), "{tag}: job {i} objective");
            }
            assert!(on.stats().autotune.enabled, "{tag}: controller reports disabled");
            assert!(!off.stats().autotune.enabled, "{tag}: off-service reports enabled");
        }
    }
}

/// Multi-worker passes are not bit-deterministic, but objectives are
/// exact and every witness must still verify — with the controller
/// live-retuning under genuine steal traffic.
#[test]
fn concurrent_answers_agree_and_verify_with_autotune_on() {
    let on = VcService::builder().workers(4).autotune(true).build();
    let off = VcService::builder().workers(4).autotune(false).build();
    let on_res = run_batch(&on);
    let off_res = run_batch(&off);
    for (i, ((g, (on_obj, _)), (off_obj, _))) in
        workload().iter().zip(&on_res).zip(&off_res).enumerate()
    {
        assert_eq!(on_obj, off_obj, "job {i}: objective diverges with autotune on");
        assert_eq!(*on_obj, oracle::mvc_size(g), "job {i} objective");
    }
    // The controller thread actually ran while the batch was in flight.
    let t0 = Instant::now();
    while on.stats().autotune.epochs == 0 {
        assert!(t0.elapsed() < Duration::from_secs(30), "controller never ticked");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn wait_bounded(h: &JobHandle, what: &str) -> Solution {
    let t0 = Instant::now();
    loop {
        if let Some(sol) = h.try_result() {
            return sol;
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "hung waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The degradation ladder outranks the controller: under injected soft
/// memory pressure every newly set-up job branches under the delta
/// representation, even when its config asks for owned frames and the
/// controller is live (and may have decided owned for every bucket).
#[test]
fn watchdog_forced_delta_outranks_the_controller() {
    let cfg = SolverConfig::proposed().with_node_repr(NodeRepr::Owned);
    let svc = VcService::builder().config(cfg).workers(2).mem_soft(1).autotune(true).build();
    // a hog keeps the ledger above the (tiny) soft limit...
    let hog = svc.submit(Problem::mvc(generators::p_hat(180, 0.35, 0.85, 11)));
    let t0 = Instant::now();
    while svc.stats().admission.live_bytes <= 1 {
        assert!(t0.elapsed() < Duration::from_secs(60), "hog never charged the ledger");
        std::thread::sleep(Duration::from_millis(2));
    }
    // ...while a latency-lane job (which bypasses the throughput hold)
    // is forced onto delta frames at setup despite its owned config: a
    // dense-enough single component so the job genuinely branches.
    let g = generators::erdos_renyi(18, 0.25, 3);
    let opt = oracle::mvc_size(&g);
    let h = svc.submit_with(
        Problem::mvc(g),
        JobOptions {
            priority: Some(Lane::Latency),
            extract_witness: true,
            ..JobOptions::default()
        },
    );
    let sol = wait_bounded(&h, "latency job under soft pressure");
    assert_eq!(sol.termination, Termination::Complete);
    assert_eq!(sol.objective, opt, "forced-delta mode changed an answer");
    assert_eq!(sol.witness_verified, Some(true));
    assert!(
        sol.stats.delta_children > 0,
        "owned-config job under soft pressure must branch on delta frames \
         (delta_children = {})",
        sol.stats.delta_children
    );
    hog.cancel();
    wait_bounded(&hog, "watchdog hog");
}
