//! Chaos suite: deterministic fault injection against the resident
//! service (the robustness tier of the test pyramid).
//!
//! Every test derives its faults from fixed seeds through
//! [`FaultPlan::from_seed`], so a failure here is replayed exactly by
//! re-running the same test binary — no flaky-crash lottery. The suite
//! asserts the service's graceful-degradation contract:
//!
//!   * **no hung waiters** — every submitted job's `wait` returns within
//!     a bounded budget, whatever was injected (setup/node/split/
//!     finalize panics, forced allocation failures, stalled workers);
//!   * **ledger reconciliation** — once every job finalized, the pool's
//!     queue-traffic conservation law holds exactly:
//!     `pops + shared_pops + steals == pushes + injected`, and the
//!     memory watchdog's live-bytes ledger drains to zero;
//!   * **blast-radius containment** — clean jobs co-scheduled with
//!     faulted ones still produce oracle-exact answers;
//!   * **witness soundness** — any job that did produce a witness
//!     (Complete, Recovered, or anytime) hands back a cover that
//!     verifies edge-by-edge against the original graph.
//!
//! Scale and shape knobs: `CAVC_CHAOS_PLANS` overrides the seeded-plan
//! count (default 200); `CAVC_CHAOS_LOG` appends one replay line per
//! plan (`FaultPlan::describe` + outcome) to the given file; the CI
//! matrix runs the suite under `CAVC_SCHED` × `CAVC_NODE_REPR`.

use cavc::graph::{generators, Graph};
use cavc::solver::faults::INJECTED_PANIC_TAG;
use cavc::solver::{
    oracle, witness, FaultPlan, JobHandle, JobOptions, Lane, Problem, RetryPolicy, SchedulerKind,
    Solution, SubmitError, Termination, VcService,
};
use std::io::Write;
use std::time::{Duration, Instant};

/// Scheduler under test: `CAVC_SCHED` (the CI chaos matrix) or the
/// default work stealer.
fn sched() -> SchedulerKind {
    std::env::var("CAVC_SCHED")
        .ok()
        .and_then(|s| SchedulerKind::parse(&s))
        .unwrap_or(SchedulerKind::WorkSteal)
}

/// Seeded fault plans per run (`CAVC_CHAOS_PLANS`, default 200).
fn plan_count() -> u64 {
    std::env::var("CAVC_CHAOS_PLANS").ok().and_then(|s| s.parse().ok()).unwrap_or(200)
}

/// Per-job wait budget. Generous: chaos graphs solve in well under a
/// second even in debug builds; a minute means a waiter is hung.
const WAIT_BUDGET: Duration = Duration::from_secs(60);

/// A bounded `wait`: the no-hung-waiters assertion. `JobHandle::wait`
/// blocks forever by design, so the chaos suite polls `try_result`
/// against a budget instead.
fn wait_bounded(h: &JobHandle, what: &str) -> Solution {
    let t0 = Instant::now();
    loop {
        if let Some(sol) = h.try_result() {
            return sol;
        }
        let id = h.id();
        assert!(t0.elapsed() < WAIT_BUDGET, "hung waiter: {what} (job {id}) past {WAIT_BUDGET:?}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// A chaos target graph: small enough to finish fast, dense enough to
/// expand a real search tree so node/split/alloc ordinals can fire.
fn chaos_graph(seed: u64) -> Graph {
    let n = 18 + (seed % 9) as usize; // 18..=26 vertices
    generators::erdos_renyi(n, 0.3, seed)
}

/// Assert a witness matches its solution: right length for MVC, and it
/// verifies edge-by-edge against the original graph.
fn assert_witness_sound(g: &Graph, sol: &Solution, what: &str) {
    let w = sol
        .witness
        .as_ref()
        .unwrap_or_else(|| panic!("{what}: missing witness ({:?})", sol.termination));
    assert_eq!(w.len() as u32, sol.objective, "{what}: |witness| != objective");
    witness::verify_cover(g, w)
        .unwrap_or_else(|e| panic!("{what}: witness failed verification: {e}"));
    assert_eq!(sol.witness_verified, Some(true), "{what}: service did not self-verify");
}

/// The headline run: `plan_count()` seeded fault plans, batched with a
/// clean oracle-checked job each, then the conservation ledgers.
#[test]
fn seeded_fault_plans_never_hang_and_ledgers_reconcile() {
    let svc = VcService::builder().workers(3).scheduler(sched()).build();
    let mut log = std::env::var("CAVC_CHAOS_LOG").ok().map(|p| {
        if let Some(dir) = std::path::Path::new(&p).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).unwrap_or_else(|e| panic!("CAVC_CHAOS_LOG dir: {e}"));
            }
        }
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&p)
            .unwrap_or_else(|e| panic!("CAVC_CHAOS_LOG={p}: {e}"))
    });
    let plans = plan_count();
    let (mut failed, mut completed) = (0u64, 0u64);
    for batch_start in (0..plans).step_by(8) {
        let mut faulty = Vec::new();
        for seed in batch_start..(batch_start + 8).min(plans) {
            let plan = FaultPlan::from_seed(seed);
            let g = chaos_graph(seed);
            let h = svc.submit_with(
                Problem::mvc(g.clone()),
                JobOptions {
                    extract_witness: true,
                    fault: Some(plan.clone()),
                    ..JobOptions::default()
                },
            );
            faulty.push((seed, plan, g, h));
        }
        // one clean job rides along with every faulted batch
        let clean_g = generators::erdos_renyi(16, 0.25, batch_start);
        let clean_opt = oracle::mvc_size(&clean_g);
        let clean = svc.submit(Problem::mvc(clean_g));

        for (seed, plan, g, h) in faulty {
            let sol = wait_bounded(&h, &format!("fault seed {seed}"));
            match sol.termination {
                Termination::Failed => {
                    failed += 1;
                    let msg = sol.failure.as_deref().unwrap_or_else(|| {
                        panic!("seed {seed}: Failed without a captured panic message")
                    });
                    assert!(
                        msg.starts_with(INJECTED_PANIC_TAG),
                        "seed {seed}: unexpected (non-injected) panic: {msg}"
                    );
                }
                Termination::Complete => {
                    // the plan's ordinals landed past the job's event
                    // stream; the answer must be fully trustworthy
                    completed += 1;
                    assert_witness_sound(&g, &sol, &format!("seed {seed}"));
                }
                t => panic!("seed {seed}: unexpected termination {t:?} (no retry/deadline set)"),
            }
            if let Some(f) = log.as_mut() {
                writeln!(f, "{} -> {:?}", plan.describe(), sol.termination)
                    .expect("chaos log write");
            }
        }
        let sol = wait_bounded(&clean, &format!("clean job of batch {batch_start}"));
        assert_eq!(sol.termination, Termination::Complete, "clean job of batch {batch_start}");
        assert_eq!(sol.objective, clean_opt, "clean job of batch {batch_start}: wrong answer");
    }
    assert!(failed > 0, "no plan fired across {plans} seeds — chaos coverage collapsed");
    assert!(completed > 0, "every plan fired — non-firing control path uncovered");

    // Quiescence: every job finalized, so the queue ledger must balance
    // exactly. Worker counters publish per processed item, so give the
    // final flush a moment before asserting. The only live bytes the
    // admission ledger may still hold are the memo cache's retained
    // component entries — anything beyond that is a leak.
    let t0 = Instant::now();
    loop {
        let s = svc.stats();
        let consumed = s.pool.pops + s.pool.shared_pops + s.pool.steals;
        let produced = s.pool.pushes + s.pool.injected;
        if consumed == produced && s.pool.backlog == 0 && s.admission.live_bytes == s.memo.bytes
        {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "ledgers did not reconcile: consumed {consumed} != produced {produced} \
             (backlog {}, live bytes {}, memo-held bytes {})",
            s.pool.backlog,
            s.admission.live_bytes,
            s.memo.bytes
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Degradation ladder rung 3: faulted jobs with a [`RetryPolicy`] are
/// rerun on the sequential solver and come back *trusted* — oracle-exact
/// objectives and verified witnesses under [`Termination::Recovered`].
#[test]
fn retry_policy_recovers_faulted_jobs_with_trusted_answers() {
    let svc = VcService::builder().workers(2).scheduler(sched()).build();
    let retry = RetryPolicy { attempts: 2, backoff: Duration::ZERO };
    let mut recovered = 0u64;
    // seeds offset from the main run's range; plus one plan that is
    // *guaranteed* to fire (setup panics are unconditional)
    let mut plans: Vec<FaultPlan> = (10_000..10_024).map(FaultPlan::from_seed).collect();
    let mut setup_plan = FaultPlan::none(99_999);
    setup_plan.panic_in_setup = true;
    plans.push(setup_plan);
    for plan in plans {
        let seed = plan.seed;
        let g = chaos_graph(seed);
        let opt = oracle::mvc_size(&g);
        let h = svc.submit_with(
            Problem::mvc(g.clone()),
            JobOptions {
                extract_witness: true,
                fault: Some(plan),
                retry: Some(retry),
                ..JobOptions::default()
            },
        );
        let sol = wait_bounded(&h, &format!("retry seed {seed}"));
        match sol.termination {
            Termination::Complete => {}
            Termination::Recovered => {
                recovered += 1;
                let msg = sol.failure.as_deref().expect("Recovered must keep the panic message");
                assert!(msg.starts_with(INJECTED_PANIC_TAG), "seed {seed}: {msg}");
            }
            t => panic!("retry seed {seed}: unexpected termination {t:?}"),
        }
        // recovered or not, the answer must be exact and witnessed
        assert_eq!(sol.objective, opt, "retry seed {seed}: wrong objective");
        assert_witness_sound(&g, &sol, &format!("retry seed {seed}"));
    }
    assert!(recovered > 0, "no job took the sequential-rescue path");
    let adm = svc.stats().admission;
    assert!(adm.retries >= recovered, "retries ({}) < recovered ({recovered})", adm.retries);
    assert_eq!(adm.recovered, recovered, "AdmissionStats.recovered miscounts");
    assert_eq!(adm.quarantined, 0, "sequential rescue must not fail on healthy graphs");
}

/// Without a retry policy the same injected faults must fail fast —
/// quarantine accounting stays at zero and `Failed` surfaces directly.
#[test]
fn setup_panic_without_retry_fails_fast() {
    let svc = VcService::builder().workers(2).scheduler(sched()).build();
    let mut plan = FaultPlan::none(7);
    plan.panic_in_setup = true;
    let h = svc.submit_with(
        Problem::mvc(chaos_graph(7)),
        JobOptions { fault: Some(plan), ..JobOptions::default() },
    );
    let sol = wait_bounded(&h, "setup panic, no retry");
    assert_eq!(sol.termination, Termination::Failed);
    let msg = sol.failure.as_deref().expect("Failed must carry the panic message");
    assert!(msg.starts_with(INJECTED_PANIC_TAG), "payload: {msg}");
    assert_eq!(svc.stats().admission.retries, 0, "no policy, no rescue attempts");
    // the pool survived and still solves
    let g = generators::erdos_renyi(16, 0.25, 3);
    let opt = oracle::mvc_size(&g);
    assert_eq!(svc.solve(Problem::mvc(g)).objective, opt);
}

/// Acceptance criterion: a deadline-expired MVC job with witness
/// extraction returns a *feasible best-so-far* cover — `|witness| ==
/// objective`, verifying against the original graph.
#[test]
fn deadline_expired_mvc_returns_feasible_anytime_witness() {
    let svc = VcService::builder().workers(2).scheduler(sched()).build();
    let g = generators::p_hat(180, 0.35, 0.85, 11); // far beyond 40ms
    let h = svc.submit_with(
        Problem::mvc(g.clone()),
        JobOptions {
            extract_witness: true,
            timeout: Some(Duration::from_millis(40)),
            ..JobOptions::default()
        },
    );
    let sol = wait_bounded(&h, "anytime deadline");
    assert_eq!(sol.termination, Termination::DeadlineExpired);
    assert!(sol.objective >= 1 && sol.objective <= 180, "bound {} out of range", sol.objective);
    assert_witness_sound(&g, &sol, "anytime deadline");
}

/// Same anytime contract on cancellation, and for MIS (the complement
/// witness path).
#[test]
fn cancelled_jobs_return_anytime_witnesses_too() {
    let svc = VcService::builder().workers(2).scheduler(sched()).build();
    for problem in [
        Problem::mvc(generators::p_hat(180, 0.35, 0.85, 11)),
        Problem::mis(generators::p_hat(180, 0.35, 0.85, 12)),
    ] {
        let g = problem.graph().as_ref().clone();
        let is_mis = matches!(problem.kind(), cavc::solver::ProblemKind::Mis);
        let h = svc.submit_with(
            problem,
            JobOptions { extract_witness: true, ..JobOptions::default() },
        );
        std::thread::sleep(Duration::from_millis(30));
        h.cancel();
        let sol = wait_bounded(&h, "anytime cancel");
        assert_eq!(sol.termination, Termination::Cancelled);
        let w = sol.witness.as_ref().expect("cancelled job must keep its best-so-far witness");
        assert_eq!(w.len() as u32, sol.objective, "|witness| != objective");
        if is_mis {
            witness::verify_independent_set(&g, w).expect("anytime MIS witness");
        } else {
            witness::verify_cover(&g, w).expect("anytime MVC witness");
        }
        assert_eq!(sol.witness_verified, Some(true));
    }
}

/// Live progress: the bound/nodes/elapsed snapshot moves while a job
/// runs and flips `done` once the outcome is published.
#[test]
fn progress_snapshots_track_a_running_job() {
    let svc = VcService::builder().workers(2).scheduler(sched()).build();
    let h = svc.submit(Problem::mvc(generators::p_hat(180, 0.35, 0.85, 11)));
    let t0 = Instant::now();
    loop {
        let p = h.progress();
        if p.best_bound.is_some() && p.nodes_expanded > 0 {
            assert!(!p.done, "progress says done before any result exists");
            break;
        }
        assert!(t0.elapsed() < WAIT_BUDGET, "job never published progress");
        std::thread::sleep(Duration::from_millis(2));
    }
    h.cancel();
    let sol = wait_bounded(&h, "progress job");
    let p = h.progress();
    assert!(p.done);
    assert_eq!(p.best_bound, Some(sol.objective), "final snapshot disagrees with the outcome");
    assert!(p.elapsed >= sol.elapsed);
}

/// Memory watchdog, soft limit: an over-budget pool degrades (forced
/// delta representation, throughput-lane dispatch held) but every job
/// still completes with exact answers, and the ledger drains to zero.
#[test]
fn watchdog_soft_limit_degrades_without_wrong_answers() {
    let svc = VcService::builder().workers(2).scheduler(sched()).mem_soft(1).build();
    // a hog keeps the ledger above the (tiny) soft limit...
    let hog = svc.submit(Problem::mvc(generators::p_hat(180, 0.35, 0.85, 11)));
    let t0 = Instant::now();
    while svc.stats().admission.live_bytes <= 1 {
        assert!(t0.elapsed() < WAIT_BUDGET, "hog never charged the ledger");
        std::thread::sleep(Duration::from_millis(2));
    }
    // ...while latency-lane jobs bypass the soft gate and stay exact
    let g = generators::erdos_renyi(16, 0.25, 1);
    let opt = oracle::mvc_size(&g);
    let h = svc.submit_with(
        Problem::mvc(g),
        JobOptions { priority: Some(Lane::Latency), ..JobOptions::default() },
    );
    let sol = wait_bounded(&h, "latency job under soft pressure");
    assert_eq!(sol.termination, Termination::Complete);
    assert_eq!(sol.objective, opt, "degraded mode changed an answer");
    // ...and throughput-lane dispatch is *held* (the job sits in the
    // admission queue rather than feeding the over-budget pool)
    let g = generators::erdos_renyi(16, 0.25, 2);
    let opt = oracle::mvc_size(&g);
    let held = svc.submit_with(
        Problem::mvc(g),
        JobOptions { priority: Some(Lane::Throughput), ..JobOptions::default() },
    );
    std::thread::sleep(Duration::from_millis(150));
    assert!(held.try_result().is_none(), "throughput job dispatched past the soft limit");
    assert!(svc.stats().admission.queued >= 1, "held job left the admission queue");
    // once the hog drains, the hold releases and the answer is exact
    hog.cancel();
    wait_bounded(&hog, "watchdog hog");
    let sol = wait_bounded(&held, "throughput job after pressure cleared");
    assert_eq!(sol.termination, Termination::Complete);
    assert_eq!(sol.objective, opt);
    // Drained means drained-to-memo: job payload bytes all release, and
    // whatever the memo cache retained is accounted on the same ledger.
    let t0 = Instant::now();
    loop {
        let s = svc.stats();
        if s.admission.live_bytes == s.memo.bytes {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "live-bytes ledger did not drain: {} live vs {} memo-held",
            s.admission.live_bytes,
            s.memo.bytes
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Memory watchdog, hard limit: past it, non-blocking submits shed with
/// [`SubmitError::MemoryPressure`]; once pressure clears, admission
/// recovers.
#[test]
fn watchdog_hard_limit_sheds_and_recovers() {
    let svc = VcService::builder().workers(2).scheduler(sched()).mem_hard(1).build();
    let hog = svc.submit(Problem::mvc(generators::p_hat(180, 0.35, 0.85, 11)));
    let t0 = Instant::now();
    while svc.stats().admission.live_bytes <= 1 {
        assert!(t0.elapsed() < WAIT_BUDGET, "hog never charged the ledger");
        std::thread::sleep(Duration::from_millis(2));
    }
    let small = generators::erdos_renyi(16, 0.25, 5);
    let opt = oracle::mvc_size(&small);
    assert_eq!(
        svc.try_submit(Problem::mvc(small.clone())).err(),
        Some(SubmitError::MemoryPressure),
        "hard limit must shed non-blocking submits"
    );
    assert!(svc.stats().admission.mem_rejected >= 1, "shed not counted");
    hog.cancel();
    wait_bounded(&hog, "watchdog hog");
    // pressure clears as the hog's queue drains; admission must recover
    let t0 = Instant::now();
    let h = loop {
        match svc.try_submit(Problem::mvc(small.clone())) {
            Ok(h) => break h,
            Err(SubmitError::MemoryPressure) => {
                assert!(t0.elapsed() < WAIT_BUDGET, "pressure never cleared after the hog drained");
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("unexpected rejection: {e}"),
        }
    };
    let sol = wait_bounded(&h, "post-pressure job");
    assert_eq!(sol.termination, Termination::Complete);
    assert_eq!(sol.objective, opt);
}
