//! The paper's §III core, under test: `graph::components` primitives and
//! the engine's *dynamic* component split — connected graphs crafted to
//! disconnect at branch depth k, whose totals can only come out right if
//! the registry's last-descendant aggregation works across nesting and
//! across racing workers.

use cavc::graph::{components, generators, Graph};
use cavc::solver::{oracle, solve_mvc, SchedulerKind, SolverConfig};

/// Nested split gadget (see `generators::split_gadget`): hub-joined
/// Petersen copies whose hubs are the unique max-degree vertices at
/// every nesting level, so covering them cascades the residual graph
/// through `d` nested splits — exercising nested registry parents and,
/// since PR 2, component-local subproblem induction.
fn nested_split(depth: usize) -> Graph {
    generators::split_gadget(depth)
}

#[test]
fn gadget_shape_is_as_designed() {
    let g1 = nested_split(1);
    assert_eq!(g1.num_vertices(), 21);
    assert_eq!(components::count(&g1), 1, "gadget must start connected");
    let hub = 20u32;
    assert_eq!(g1.degree(hub), 12); // 2·(5 + depth) hub spokes
    // hub strictly dominates every other degree
    let snd = (0..20u32).map(|v| g1.degree(v)).max().unwrap();
    assert!(g1.degree(hub) > snd, "hub must be the unique branch vertex");
}

#[test]
fn components_primitives_agree_on_gadgets() {
    for depth in 0..3usize {
        let g = nested_split(depth);
        let (labels, k) = components::labels(&g);
        assert_eq!(k, 1, "depth {depth}");
        assert_eq!(labels.len(), g.num_vertices());
        assert_eq!(components::count_union_find(&g), 1, "depth {depth}");
        // removing the hub splits it in two
        if depth > 0 {
            let hub = (g.num_vertices() - 1) as u32;
            let kept: Vec<(u32, u32)> =
                g.edges().filter(|&(u, v)| u != hub && v != hub).collect();
            let cut = Graph::from_edges(g.num_vertices(), &kept);
            // hub becomes isolated, so: 2 halves + 1 singleton
            assert_eq!(components::count(&cut), 3, "depth {depth}");
            let sets = components::vertex_sets(&cut);
            let total: usize = sets.iter().map(|s| s.len()).sum();
            assert_eq!(total, g.num_vertices());
        }
    }
}

#[test]
fn components_vertex_sets_partition_disconnected_unions() {
    for seed in 0..8u64 {
        let g = generators::union_of_random(6, 3, 8, 0.3, seed);
        let sets = components::vertex_sets(&g);
        assert_eq!(sets.len(), 6, "seed {seed}");
        let mut seen = vec![false; g.num_vertices()];
        for s in &sets {
            for &v in s {
                assert!(!seen[v as usize], "seed {seed}: vertex {v} in two sets");
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "seed {seed}: vertex missing from partition");
        // every edge stays within one set
        let (labels, _) = components::labels(&g);
        for (u, v) in g.edges() {
            assert_eq!(labels[u as usize], labels[v as usize], "seed {seed}");
        }
    }
}

#[test]
fn bfs_reach_stops_at_cut() {
    let g = nested_split(1);
    let hub = 20u32;
    let kept: Vec<(u32, u32)> = g.edges().filter(|&(u, v)| u != hub && v != hub).collect();
    let cut = Graph::from_edges(g.num_vertices(), &kept);
    let reach = components::bfs_reach(&cut, 0);
    assert_eq!(reach.count(), 10, "one Petersen half");
    assert!(!reach.get(10), "other half unreachable");
    assert!(!reach.get(20), "hub unreachable");
}

#[test]
fn engine_splits_at_depth_k_and_aggregates() {
    // depth 1 and 2 fit the 64-vertex oracle
    for depth in 1..=2usize {
        let g = nested_split(depth);
        let opt = oracle::mvc_size(&g);
        for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
            for workers in [1usize, 2, 4] {
                let cfg = SolverConfig::proposed().with_workers(workers).with_scheduler(sched);
                let r = solve_mvc(&g, &cfg);
                assert_eq!(
                    r.best,
                    opt,
                    "depth {depth} workers {workers} {}: aggregation broke the total",
                    sched.name()
                );
                assert!(
                    r.stats.component_branches >= 1,
                    "depth {depth} workers {workers} {}: no dynamic split on a splitting gadget",
                    sched.name()
                );
            }
        }
    }
}

#[test]
fn deep_gadget_matches_sequential_reference() {
    // depth 3 (87 vertices) is beyond the oracle; the sequential solver
    // with component awareness is the reference.
    let g = nested_split(3);
    let seq = solve_mvc(&g, &SolverConfig::sequential());
    assert!(!seq.timed_out);
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let cfg = SolverConfig::proposed().with_workers(4).with_scheduler(sched);
        let r = solve_mvc(&g, &cfg);
        assert_eq!(r.best, seq.best, "{}", sched.name());
        assert!(r.stats.component_branches >= 2, "{}: nested splits expected", sched.name());
    }
}

#[test]
fn induction_matches_full_width_on_gadgets() {
    // The gadget splits at depth k: the induced run must agree with the
    // full-width run and actually materialize compact subproblems.
    for depth in 1..=2usize {
        let g = nested_split(depth);
        let opt = oracle::mvc_size(&g);
        for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
            let on = solve_mvc(
                &g,
                &SolverConfig::proposed().with_workers(4).with_scheduler(sched),
            );
            let off = solve_mvc(
                &g,
                &SolverConfig::proposed()
                    .with_workers(4)
                    .with_scheduler(sched)
                    .with_induce_threshold(0.0),
            );
            assert_eq!(on.best, opt, "depth {depth} {} induced", sched.name());
            assert_eq!(off.best, opt, "depth {depth} {} full-width", sched.name());
            assert!(
                on.stats.induced_subproblems >= 2,
                "depth {depth} {}: split must induce subproblems",
                sched.name()
            );
            assert_eq!(
                off.stats.induced_subproblems,
                0,
                "depth {depth} {}: threshold 0 must disable induction",
                sched.name()
            );
        }
    }
}

#[test]
fn racy_split_aggregation_is_stable() {
    // Re-run the same splitting search many times with many workers: a
    // lost or double-counted last-descendant cascade shows up as a
    // nondeterministic total.
    let g = nested_split(2);
    let expect = solve_mvc(&g, &SolverConfig::sequential()).best;
    for trial in 0..25 {
        let cfg = SolverConfig::proposed().with_workers(8);
        let r = solve_mvc(&g, &cfg);
        assert_eq!(r.best, expect, "trial {trial}");
    }
}

#[test]
fn histogram_accounts_for_every_split() {
    let g = nested_split(2);
    let r = solve_mvc(&g, &SolverConfig::proposed().with_workers(4));
    let hist_total: u64 = r.stats.comp_histogram.values().sum();
    assert_eq!(hist_total, r.stats.component_branches);
    // splits here produce exactly 2 components at a time
    for (&parts, &count) in &r.stats.comp_histogram {
        assert!(parts >= 2, "split with {parts} parts recorded {count} times");
    }
}
