//! Witness validity: extracted covers are genuine vertex covers of
//! optimal size, and the greedy/matching bounds bracket the optimum.

use cavc::graph::{generators, Graph};
use cavc::solver::{greedy, oracle, solve_mvc, SolverConfig};
use cavc::util::SplitMix64;

fn extract_cfg() -> SolverConfig {
    let mut cfg = SolverConfig::sequential();
    cfg.extract_cover = true;
    cfg
}

#[test]
fn sequential_witnesses_are_optimal_covers() {
    let mut rng = SplitMix64::new(0xC0FE);
    for trial in 0..40 {
        let n = rng.range(6, 20);
        let p = 0.08 + rng.next_f64() * 0.3;
        let g = generators::erdos_renyi(n, p, rng.next_u64());
        let opt = oracle::mvc_size(&g);
        let r = solve_mvc(&g, &extract_cfg());
        assert_eq!(r.best, opt, "trial {trial}");
        if let Some(c) = &r.cover {
            assert!(g.is_vertex_cover(c), "trial {trial}: not a cover");
            assert_eq!(c.len() as u32, opt, "trial {trial}: wrong size");
            // no duplicates
            let set: std::collections::HashSet<_> = c.iter().collect();
            assert_eq!(set.len(), c.len(), "trial {trial}: duplicate vertices");
        }
    }
}

#[test]
fn witnesses_on_splitting_graphs() {
    for seed in 0..12 {
        let g = generators::union_of_random(4, 3, 8, 0.3, seed);
        let opt = oracle::mvc_size(&g);
        let r = solve_mvc(&g, &extract_cfg());
        assert_eq!(r.best, opt, "seed {seed}");
        if let Some(c) = &r.cover {
            assert!(g.is_vertex_cover(c), "seed {seed}");
            assert_eq!(c.len() as u32, opt, "seed {seed}");
        }
    }
}

#[test]
fn witnesses_on_special_components() {
    // unions of cliques and cycles exercise the §III-D closed forms
    let g = Graph::disjoint_union(&[
        generators::clique(6),
        generators::cycle(9),
        generators::cycle(8),
        generators::clique(4),
    ]);
    let opt = oracle::mvc_size(&g);
    assert_eq!(opt, 5 + 5 + 4 + 3);
    let r = solve_mvc(&g, &extract_cfg());
    assert_eq!(r.best, opt);
    if let Some(c) = &r.cover {
        assert!(g.is_vertex_cover(c));
        assert_eq!(c.len() as u32, opt);
    }
}

#[test]
fn witness_respects_crown_and_root_reduction() {
    // graphs that reduce heavily at the root: the translated witness must
    // still cover the *original* graph
    for seed in 0..8 {
        let g = generators::web_crawl(30, 120, seed);
        let r = solve_mvc(&g, &extract_cfg());
        if let Some(c) = &r.cover {
            assert!(g.is_vertex_cover(c), "seed {seed}");
            assert_eq!(c.len() as u32, r.best, "seed {seed}");
        }
        // parallel result must agree
        let p = solve_mvc(&g, &SolverConfig::proposed());
        assert_eq!(p.best, r.best, "seed {seed}");
    }
}

#[test]
fn bounds_bracket_the_optimum() {
    let mut rng = SplitMix64::new(0xB0);
    for trial in 0..30 {
        let n = rng.range(6, 22);
        let g = generators::erdos_renyi(n, 0.2, rng.next_u64());
        let opt = oracle::mvc_size(&g);
        let gre = greedy::greedy_bound(&g);
        assert!(gre >= opt, "trial {trial}: greedy below optimum");
        let matching = greedy::matching_cover(&g);
        assert!(g.is_vertex_cover(&matching), "trial {trial}");
        assert!(matching.len() as u32 <= 2 * opt.max(1), "trial {trial}: 2-approx broken");
    }
}
