//! Differential tests for the delta/undo node representation
//! (speculative in-place branching with steal-time materialization).
//!
//! The owned representation copies every right child's payload, so a
//! stolen node is trivially self-contained; the delta representation
//! must reconstruct stolen state by replaying pinned cover suffixes.
//! These tests force high steal rates — more workers than components,
//! deep single-component searches at 4/16 workers on both schedulers —
//! and differentially check objectives *and verified witnesses* against
//! the sequential solver in both node representations.

use cavc::graph::{generators, Graph};
use cavc::solver::{self, NodeRepr, SchedulerKind, SolverConfig};

const SCHEDULERS: [SchedulerKind; 2] = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];
const REPRS: [NodeRepr; 2] = [NodeRepr::Owned, NodeRepr::Delta];
const WORKERS: [usize; 2] = [4, 16];

/// The seeded workload mix: single deep components (every queued node
/// is a delta child, so any steal must materialize), component unions
/// (splits interleave owned and delta children), and nested split
/// gadgets (the paper's split-heavy family).
fn workloads() -> Vec<(String, Graph)> {
    let mut w = Vec::new();
    for seed in 0..4u64 {
        w.push((
            format!("er(22,0.22,{seed})"),
            generators::erdos_renyi(22, 0.22, seed),
        ));
        w.push((
            format!("union(4,3,7,{seed})"),
            generators::union_of_random(4, 3, 7, 0.3, seed),
        ));
    }
    w.push(("split_gadget(2)".into(), generators::split_gadget(2)));
    w.push(("split_gadget(3)".into(), generators::split_gadget(3)));
    w
}

fn parallel_cfg(repr: NodeRepr, sched: SchedulerKind, workers: usize) -> SolverConfig {
    let mut cfg = SolverConfig::proposed()
        .with_node_repr(repr)
        .with_scheduler(sched)
        .with_workers(workers);
    cfg.extract_cover = true;
    cfg
}

#[test]
fn high_steal_objectives_and_witnesses_match_sequential() {
    for (name, g) in workloads() {
        let mut seq_cfg = SolverConfig::sequential();
        seq_cfg.extract_cover = true;
        let seq = solver::solve_mvc(&g, &seq_cfg);
        let seq_cover = seq.cover.as_ref().expect("sequential witness");
        assert!(g.is_vertex_cover(seq_cover), "{name}: sequential cover invalid");
        assert_eq!(seq_cover.len() as u32, seq.best, "{name}");

        for repr in REPRS {
            for sched in SCHEDULERS {
                for workers in WORKERS {
                    let tag = format!("{name} {} {} w={workers}", repr.name(), sched.name());
                    let r = solver::solve_mvc(&g, &parallel_cfg(repr, sched, workers));
                    assert!(!r.timed_out, "{tag}: must run to completion");
                    assert_eq!(r.best, seq.best, "{tag}: objective differs from sequential");
                    let c = r.cover.as_ref().expect("parallel witness");
                    assert_eq!(c.len() as u32, r.best, "{tag}: witness length");
                    assert!(g.is_vertex_cover(c), "{tag}: witness invalid");
                }
            }
        }
    }
}

#[test]
fn high_steal_pvc_decisions_match_sequential() {
    for (name, g) in workloads().into_iter().step_by(2) {
        let opt = solver::solve_mvc(&g, &SolverConfig::sequential()).best;
        for repr in REPRS {
            for sched in SCHEDULERS {
                for workers in WORKERS {
                    let tag = format!("{name} {} {} w={workers}", repr.name(), sched.name());
                    let cfg = parallel_cfg(repr, sched, workers);
                    let yes = solver::solve_pvc(&g, opt, &cfg);
                    assert!(yes.found, "{tag}: k=opt must be feasible");
                    let c = yes.cover.as_ref().expect("found PVC carries a cover");
                    assert!(c.len() as u32 <= opt, "{tag}: PVC cover within k");
                    assert!(g.is_vertex_cover(c), "{tag}: PVC cover invalid");
                    if opt > 0 {
                        let no = solver::solve_pvc(&g, opt - 1, &cfg);
                        assert!(!no.found, "{tag}: k=opt-1 must be infeasible");
                    }
                }
            }
        }
    }
}

#[test]
fn sixteen_workers_on_one_component_exercise_materialization() {
    // A single connected component in delta mode queues only delta
    // children after the root, so every cross-worker steal must
    // materialize. Individual runs are scheduling-dependent; across the
    // seed sweep at 16 workers the work stealer reliably steals.
    let mut steals = 0u64;
    let mut materializations = 0u64;
    let mut undo_pops = 0u64;
    for seed in 0..8u64 {
        let g = generators::erdos_renyi(24, 0.25, seed);
        let cfg = SolverConfig::proposed()
            .with_node_repr(NodeRepr::Delta)
            .with_workers(16);
        let r = solver::solve_mvc(&g, &cfg);
        let seq = solver::solve_mvc(&g, &SolverConfig::sequential());
        assert_eq!(r.best, seq.best, "seed {seed}");
        steals += r.stats.worklist_steals;
        materializations += r.stats.materializations;
        undo_pops += r.stats.undo_pops;
    }
    assert!(undo_pops > 0, "local pops must take the undo path");
    assert!(steals > 0, "16 workers over 8 seeds must steal at least once");
    assert!(
        materializations > 0,
        "stolen delta children must materialize (steals={steals})"
    );
}

#[test]
fn service_jobs_agree_across_reprs_and_report_class_stats() {
    // Delta vs owned through the resident service: concurrent jobs of
    // both classes, then the pool-level stats endpoint must account for
    // the finished jobs per class.
    let svc = solver::VcService::builder().workers(4).build();
    let mut handles = Vec::new();
    for seed in 0..6u64 {
        // dense-enough single components so delta jobs genuinely branch
        // (pure-reduction graphs would push no delta children)
        let g = generators::erdos_renyi(18, 0.25, seed);
        let opt = solver::solve_mvc(&g, &SolverConfig::sequential()).best;
        for repr in REPRS {
            let cfg = SolverConfig::proposed().with_node_repr(repr);
            let opts = solver::JobOptions {
                config: Some(cfg),
                extract_witness: true,
                ..Default::default()
            };
            handles.push((
                seed,
                repr,
                opt,
                g.clone(),
                svc.submit_with(solver::Problem::mvc(g.clone()), opts),
            ));
        }
    }
    let jobs = handles.len() as u64;
    for (seed, repr, opt, g, h) in handles {
        let sol = h.wait();
        let tag = format!("seed {seed} {}", repr.name());
        assert_eq!(sol.objective, opt, "{tag}");
        let w = sol.witness.as_ref().expect("service witness");
        assert!(g.is_vertex_cover(w), "{tag}");
        assert_eq!(sol.witness_verified, Some(true), "{tag}");
    }
    // Class counters are folded at finalization, so they are exact once
    // every `wait` returned; pool counters are flushed when workers go
    // idle, which can trail the last job by a scheduling beat.
    let mut stats = svc.stats();
    for _ in 0..400 {
        if stats.pool.pushes > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
        stats = svc.stats();
    }
    assert_eq!(stats.mvc.jobs, jobs, "every finished job lands in its class");
    assert!(stats.mvc.tree_nodes > 0);
    assert!(stats.mvc.delta_children > 0, "delta jobs must push delta children");
    assert!(stats.pool.pushes > 0, "pool counters must be flushed");
    assert_eq!(stats.pvc.jobs, 0);
    assert_eq!(stats.mis.jobs, 0);
}
