//! Differential test suite: the parallel engine must agree with the
//! sequential solver AND the brute-force oracle on every randomized
//! instance, for MVC and PVC, across all three paper variants and both
//! scheduling runtimes.
//!
//! Property-style generation without a `proptest` dependency (the build
//! is offline): a seeded `SplitMix64` drives a graph-family pool —
//! Erdős–Rényi, random trees, cliques-with-bridges, and disconnected
//! unions from `graph::generators` — so every run replays the exact same
//! ≥200 cases per variant. A failing case prints its reproducible tag.

use cavc::graph::{generators, Graph};
use cavc::solver::witness::verify_cover;
use cavc::solver::{
    oracle, sequential, solve_mvc, solve_pvc, JobOptions, Problem, SchedulerKind, SolverConfig,
    Termination, VcService,
};
use cavc::util::SplitMix64;

const CASES: usize = 220;
const SEED: u64 = 0xD1FF_0001;

/// Cliques chained by bridge edges: reduction-resistant dense blobs that
/// split the moment a bridge endpoint enters the cover.
fn cliques_with_bridges(num: usize, lo: usize, hi: usize, rng: &mut SplitMix64) -> Graph {
    let sizes: Vec<usize> = (0..num).map(|_| rng.range(lo, hi)).collect();
    let parts: Vec<Graph> = sizes.iter().map(|&s| generators::clique(s)).collect();
    let mut edges: Vec<(u32, u32)> = Graph::disjoint_union(&parts).edges().collect();
    // bridge: last vertex of part i — first vertex of part i+1
    let mut off = 0u32;
    for w in sizes.windows(2) {
        let bridge_from = off + w[0] as u32 - 1;
        let bridge_to = off + w[0] as u32;
        edges.push((bridge_from, bridge_to));
        off += w[0] as u32;
    }
    Graph::from_edges(sizes.iter().sum(), &edges)
}

/// One deterministic case from the family pool.
fn random_case(rng: &mut SplitMix64) -> (Graph, String) {
    let kind = rng.index(4);
    let seed = rng.next_u64();
    match kind {
        0 => {
            let n = rng.range(6, 24);
            let p = 0.08 + rng.next_f64() * 0.32;
            (generators::erdos_renyi(n, p, seed), format!("er({n},{p:.2},{seed})"))
        }
        1 => {
            let n = rng.range(4, 32);
            (generators::random_tree(n, seed), format!("tree({n},{seed})"))
        }
        2 => {
            let num = rng.range(2, 4);
            let g = cliques_with_bridges(num, 3, 6, rng);
            (g, format!("cliques+bridges({num})"))
        }
        _ => {
            let parts = rng.range(2, 5);
            (
                generators::union_of_random(parts, 3, 7, 0.3, seed),
                format!("union({parts},{seed})"),
            )
        }
    }
}

fn parallel_variants() -> Vec<SolverConfig> {
    vec![SolverConfig::proposed(), SolverConfig::prior_work(), SolverConfig::no_load_balance()]
}

/// Sequential reference through the public solver pipeline.
fn sequential_best(g: &Graph) -> u32 {
    solve_mvc(g, &SolverConfig::sequential()).best
}

#[test]
fn differential_mvc_all_variants() {
    let mut rng = SplitMix64::new(SEED);
    let workers = [1usize, 2, 3, 4, 8];
    let schedulers = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];
    let mut ran = 0usize;
    for case in 0..CASES {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        assert_eq!(sequential_best(&g), opt, "case {case} {tag}: sequential");
        let w = workers[case % workers.len()];
        let sched = schedulers[case % schedulers.len()];
        for cfg in parallel_variants() {
            let cfg = cfg.with_workers(w).with_scheduler(sched);
            let r = solve_mvc(&g, &cfg);
            assert!(!r.timed_out, "case {case} {tag}: {} timed out", cfg.variant.name());
            assert_eq!(
                r.best,
                opt,
                "case {case} {tag}: {}({} workers, {}) != oracle",
                cfg.variant.name(),
                w,
                sched.name()
            );
        }
        ran += 1;
    }
    assert!(ran >= 200, "only {ran} cases ran; generator drift?");
}

#[test]
fn differential_pvc_all_variants() {
    let mut rng = SplitMix64::new(SEED ^ 0xBEEF);
    let workers = [1usize, 2, 4];
    let schedulers = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];
    let mut ran = 0usize;
    for case in 0..CASES {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        let w = workers[case % workers.len()];
        let sched = schedulers[case % schedulers.len()];
        for cfg in parallel_variants() {
            let cfg = cfg.with_workers(w).with_scheduler(sched);
            let at = solve_pvc(&g, opt, &cfg);
            assert!(at.found, "case {case} {tag}: {} missed k=opt", cfg.variant.name());
            assert!(at.size.unwrap() <= opt, "case {case} {tag}: size above k");
            let below = solve_pvc(&g, opt.saturating_sub(1), &cfg);
            assert!(
                !below.found,
                "case {case} {tag}: {} found a cover below the optimum",
                cfg.variant.name()
            );
        }
        // sequential PVC reference
        let seq = solve_pvc(&g, opt, &SolverConfig::sequential());
        assert!(seq.found, "case {case} {tag}: sequential missed k=opt");
        ran += 1;
    }
    assert!(ran >= 200, "only {ran} cases ran; generator drift?");
}

#[test]
fn differential_induction_on_off() {
    // Component-local subproblem induction must be invisible in results:
    // identical `best` for full-width and induced subproblems, for MVC
    // and PVC, across both schedulers, on graphs built to split — the
    // seeded gadget/union/bridge families plus random mixes.
    let mut rng = SplitMix64::new(SEED ^ 0x17DC_E000);
    let schedulers = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];
    let thresholds = [0.0, 0.35, 1.0];
    let mut cases: Vec<(Graph, String)> = vec![
        (generators::split_gadget(1), "split_gadget(1)".into()),
        (generators::split_gadget(2), "split_gadget(2)".into()),
    ];
    for case in 0..24 {
        let (g, tag) = match case % 3 {
            0 => {
                let seed = rng.next_u64();
                (generators::union_of_random(3, 3, 7, 0.3, seed), format!("union({seed})"))
            }
            1 => {
                let num = rng.range(2, 4);
                (cliques_with_bridges(num, 3, 6, &mut rng), format!("cliques+bridges({num})"))
            }
            _ => {
                let n = rng.range(8, 22);
                let p = 0.1 + rng.next_f64() * 0.25;
                let seed = rng.next_u64();
                (generators::erdos_renyi(n, p, seed), format!("er({n},{p:.2},{seed})"))
            }
        };
        cases.push((g, tag));
    }
    for (case, (g, tag)) in cases.iter().enumerate() {
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(g);
        let workers = 1 + case % 4;
        for sched in schedulers {
            for &t in &thresholds {
                let cfg = SolverConfig::proposed()
                    .with_workers(workers)
                    .with_scheduler(sched)
                    .with_induce_threshold(t);
                let r = solve_mvc(g, &cfg);
                assert!(!r.timed_out, "case {case} {tag}: timed out");
                assert_eq!(
                    r.best,
                    opt,
                    "case {case} {tag}: induce={t} ({}, {workers} workers) != oracle",
                    sched.name()
                );
                let pvc = solve_pvc(g, opt, &cfg);
                assert!(pvc.found, "case {case} {tag}: induce={t} PVC missed k=opt");
                assert!(
                    !solve_pvc(g, opt.saturating_sub(1), &cfg).found,
                    "case {case} {tag}: induce={t} PVC found below optimum"
                );
            }
        }
    }
}

#[test]
fn differential_concurrent_service_mixed_jobs() {
    // Concurrent submission of mixed MVC/PVC jobs to one resident pool
    // must equal the sequential oracle answers — the jobs interleave on
    // shared deques, so this exercises job-local registry scoping,
    // per-job completion counting, and the setup/run split, on both
    // resident runtimes.
    let mut rng = SplitMix64::new(SEED ^ 0x5E41_11CE);
    let mut cases: Vec<(Graph, u32, String)> = Vec::new();
    for case in 0..80 {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        cases.push((g, opt, format!("case {case} {tag}")));
    }
    assert!(cases.len() >= 40, "generator drift: only {} cases", cases.len());
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(4).scheduler(sched).build();
        // submit everything before waiting on anything: all jobs in
        // flight at once; even-indexed jobs additionally extract their
        // witness, so objectives AND covers are differentially checked
        let handles: Vec<_> = cases
            .iter()
            .enumerate()
            .map(|(i, (g, opt, _))| {
                let opts =
                    JobOptions { extract_witness: i % 2 == 0, ..JobOptions::default() };
                match i % 3 {
                    0 => svc.submit_with(Problem::mvc(g.clone()), opts),
                    1 => svc.submit_with(Problem::pvc(g.clone(), *opt), opts),
                    _ => svc.submit_with(Problem::pvc(g.clone(), opt - 1), opts),
                }
            })
            .collect();
        for (i, h) in handles.iter().enumerate() {
            let (g, opt, tag) = &cases[i];
            let sol = h.wait();
            assert_eq!(
                sol.termination,
                Termination::Complete,
                "{tag} ({}) did not complete",
                sched.name()
            );
            let extracting = i % 2 == 0;
            match i % 3 {
                0 => {
                    assert_eq!(sol.objective, *opt, "{tag} ({}): mvc != oracle", sched.name());
                    if extracting {
                        let w = sol.witness.as_ref().expect("mvc witness requested");
                        assert_eq!(w.len() as u32, *opt, "{tag}: |witness| != objective");
                        verify_cover(g, w)
                            .unwrap_or_else(|e| panic!("{tag} ({}): {e}", sched.name()));
                        assert_eq!(sol.witness_verified, Some(true), "{tag}");
                    } else {
                        assert!(sol.witness.is_none(), "{tag}: unrequested witness");
                    }
                }
                1 => {
                    assert!(sol.feasible, "{tag} ({}): pvc missed k=opt", sched.name());
                    assert!(sol.objective <= *opt, "{tag}: pvc size above k");
                    if extracting {
                        let w = sol.witness.as_ref().expect("pvc witness requested");
                        assert!(w.len() as u32 <= *opt, "{tag}: pvc witness above k");
                        verify_cover(g, w)
                            .unwrap_or_else(|e| panic!("{tag} ({}): {e}", sched.name()));
                    }
                }
                _ => assert!(
                    !sol.feasible,
                    "{tag} ({}): pvc found a cover below the optimum",
                    sched.name()
                ),
            }
        }
    }
}

#[test]
fn differential_runs_are_deterministic() {
    // The same seed must generate the same case list — the suite's
    // reproducibility contract.
    let mut a = SplitMix64::new(SEED);
    let mut b = SplitMix64::new(SEED);
    for case in 0..CASES {
        let (ga, ta) = random_case(&mut a);
        let (gb, tb) = random_case(&mut b);
        assert_eq!(ta, tb, "case {case}");
        assert_eq!(ga, gb, "case {case}");
    }
}

#[test]
fn differential_witnesses_on_split_graphs() {
    // Sequential extraction yields genuine optimal covers on the
    // families where the engine splits components.
    let mut rng = SplitMix64::new(SEED ^ 0xC0FE);
    let mut cfg = SolverConfig::sequential();
    cfg.extract_cover = true;
    for case in 0..30 {
        let num = rng.range(2, 4);
        let g = cliques_with_bridges(num, 3, 6, &mut rng);
        let opt = oracle::mvc_size(&g);
        let r = solve_mvc(&g, &cfg);
        assert_eq!(r.best, opt, "case {case}");
        if let Some(c) = &r.cover {
            assert!(g.is_vertex_cover(c), "case {case}: invalid witness");
            assert_eq!(c.len() as u32, opt, "case {case}: suboptimal witness");
        }
    }
    // direct cross-check of the sequential module against the oracle
    for seed in 0..20u64 {
        let g = generators::erdos_renyi(14, 0.25, seed);
        let ub = g.num_vertices() as u32 + 1;
        let out = sequential::solve(&g, ub, true, false, None);
        assert_eq!(out.best, oracle::mvc_size(&g), "seed {seed}");
    }
}
