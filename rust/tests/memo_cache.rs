//! Differential tests for the cross-job component memo cache
//! ([`cavc::solver::memo`]): a warm resident service must return the
//! same objectives — and, on serial runs, bit-identical verified
//! witnesses — as a cold one, while actually hitting the cache; PVC
//! (decision-bounded) jobs must never publish; and `--memo off` must be
//! fully inert.

use cavc::graph::generators;
use cavc::solver::engine::NodeRepr;
use cavc::solver::{
    oracle, JobOptions, MemoStats, Problem, SchedulerKind, SolverConfig, Termination, VcService,
};

/// Component-rich workloads: unions of small random parts, so every job
/// splits into several induced components and resubmission re-derives
/// the same canonical CSR forms.
fn workload() -> Vec<cavc::graph::Graph> {
    (0..6u64).map(|seed| generators::union_of_random(4, 4, 8, 0.35, seed)).collect()
}

fn extract_opts() -> JobOptions {
    JobOptions { extract_witness: true, ..JobOptions::default() }
}

/// Run the workload once through `svc`, returning (objective, witness)
/// per job after asserting completion and witness verification.
fn run_batch(svc: &VcService) -> Vec<(u32, Vec<u32>)> {
    let handles: Vec<_> = workload()
        .into_iter()
        .map(|g| svc.submit_with(Problem::mvc(g), extract_opts()))
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(i, h)| {
            let sol = h.wait();
            assert_eq!(sol.termination, Termination::Complete, "job {i}");
            assert_eq!(sol.witness_verified, Some(true), "job {i}: witness must verify");
            (sol.objective, sol.witness.expect("extracting job returns a witness"))
        })
        .collect()
}

#[test]
fn warm_resubmission_is_bit_identical_on_serial_runs() {
    // One worker keeps both passes deterministic, so the warm pass must
    // reproduce the cold answers *and* the exact same (sorted) covers —
    // a cache hit substitutes the published component cover for the
    // cold run's freshly searched one, and those are the same arrays.
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        for repr in [NodeRepr::Owned, NodeRepr::Delta] {
            let cfg = SolverConfig::proposed().with_node_repr(repr);
            let svc =
                VcService::builder().config(cfg).scheduler(sched).workers(1).build();
            let cold = run_batch(&svc);
            let after_cold = svc.stats().memo;
            let warm = run_batch(&svc);
            let after_warm = svc.stats().memo;
            let tag = format!("{}/{}", sched.name(), repr.name());
            assert_eq!(cold, warm, "{tag}: warm answers/witnesses diverge from cold");
            assert!(
                after_cold.inserts > 0,
                "{tag}: cold pass published nothing — components never reached the cache"
            );
            assert!(
                after_warm.hits > after_cold.hits,
                "{tag}: warm resubmission produced no cache hits \
                 (cold {after_cold:?}, warm {after_warm:?})"
            );
            // exact MVC sanity against the oracle
            for (i, (g, (obj, _))) in workload().iter().zip(&cold).enumerate() {
                assert_eq!(*obj, oracle::mvc_size(g), "{tag}: job {i} objective");
            }
        }
    }
}

#[test]
fn warm_resubmission_hits_and_verifies_under_concurrency() {
    // Multi-worker passes are not bit-deterministic, but objectives are
    // exact and every witness must still verify; the warm pass must hit.
    let svc = VcService::builder().workers(4).build();
    let cold = run_batch(&svc);
    let warm = run_batch(&svc);
    for (i, ((c, _), (w, _))) in cold.iter().zip(&warm).enumerate() {
        assert_eq!(c, w, "job {i}: warm objective diverges from cold");
    }
    let m = svc.stats().memo;
    assert!(m.hits > 0, "warm resubmission produced no cache hits: {m:?}");
    assert!(m.lookups >= m.hits, "hits cannot exceed lookups: {m:?}");
    assert!(m.saved_nodes > 0, "hits must account skipped subtree nodes: {m:?}");
}

#[test]
fn pvc_jobs_never_publish_to_the_cache() {
    // PVC searches prune against the budget k, so their component
    // results are bounded, not exact — the cache must never see them.
    let svc = VcService::builder().workers(2).build();
    for seed in 0..4u64 {
        let g = generators::union_of_random(3, 4, 8, 0.35, seed);
        let k = oracle::mvc_size(&g);
        let sol = svc.submit_with(Problem::pvc(g, k), extract_opts()).wait();
        assert_eq!(sol.termination, Termination::Complete, "seed {seed}");
        assert!(sol.feasible, "seed {seed}: k = exact MVC must be feasible");
    }
    let m = svc.stats().memo;
    assert_eq!(m.inserts, 0, "PVC results were published: {m:?}");
    assert_eq!(m.bytes, 0, "cache holds bytes no job published: {m:?}");
}

#[test]
fn memo_off_is_fully_inert() {
    // `--memo off` (builder form) must leave zero trace: no lookups, no
    // inserts, no held bytes — both passes run the plain search.
    let svc = VcService::builder().workers(2).memo(false).build();
    let cold = run_batch(&svc);
    let warm = run_batch(&svc);
    for (i, ((c, _), (w, _))) in cold.iter().zip(&warm).enumerate() {
        assert_eq!(c, w, "job {i}: objectives must agree without the cache");
    }
    assert_eq!(svc.stats().memo, MemoStats::default(), "memo off must be inert");
}

#[test]
fn per_job_opt_out_skips_the_cache() {
    // A job submitted with `memo: Some(false)` on a memo-enabled service
    // neither consults nor feeds the cache.
    let svc = VcService::builder().workers(2).build();
    let g = generators::union_of_random(4, 4, 8, 0.35, 99);
    let opt = oracle::mvc_size(&g);
    let opts = JobOptions { memo: Some(false), ..extract_opts() };
    let sol = svc.submit_with(Problem::mvc(g), opts).wait();
    assert_eq!(sol.objective, opt);
    assert_eq!(svc.stats().memo, MemoStats::default(), "opted-out job touched the cache");
}
