//! End-to-end pipeline over the benchmark suite: prepare → (optionally
//! XLA-split) → parallel search, with cross-variant agreement on the
//! fast datasets and sane table-row generation on the rest.

use cavc::harness::{datasets, tables};
use cavc::solver::{solve_mvc, SolverConfig};

#[test]
fn smoke_suite_all_variants_agree() {
    std::env::set_var("CAVC_TIMEOUT_S", "20");
    for d in datasets::smoke_suite() {
        let g = d.build();
        let mut answers = Vec::new();
        for cfg in [
            SolverConfig::proposed(),
            SolverConfig::sequential(),
            SolverConfig::no_load_balance(),
        ] {
            let cfg = cfg.with_timeout(std::time::Duration::from_secs(20));
            let r = solve_mvc(&g, &cfg);
            if !r.timed_out {
                answers.push((cfg.variant.name(), r.best));
            }
        }
        assert!(!answers.is_empty(), "{}: every variant timed out", d.name);
        let first = answers[0].1;
        for (name, best) in &answers {
            assert_eq!(*best, first, "{}: {name} disagrees", d.name);
        }
    }
}

#[test]
fn proposed_beats_trivial_bound_on_suite() {
    std::env::set_var("CAVC_TIMEOUT_S", "20");
    for d in datasets::smoke_suite() {
        let g = d.build();
        let r = tables::run_mvc(&g, SolverConfig::proposed());
        assert!(!r.timed_out, "{} timed out", d.name);
        assert!(r.best < g.num_vertices() as u32, "{}: trivial answer", d.name);
        assert!(r.best > 0, "{}: zero cover on a graph with edges", d.name);
    }
}

#[test]
fn table4_rows_reproduce_paper_shape() {
    // The qualitative claims of Table IV on our analogs: reduction never
    // grows the array, never reduces blocks, and always enables short
    // dtypes at analog scale.
    for d in datasets::suite() {
        let row = tables::table4_row(&d);
        assert!(row.n_after <= row.n_before, "{}", d.name);
        assert!(row.blocks_after >= row.blocks_before, "{}", d.name);
        assert!(row.short_after, "{}: expected short dtype after", d.name);
    }
}

#[test]
fn splitting_dataset_visits_fewer_nodes_with_components() {
    std::env::set_var("CAVC_TIMEOUT_S", "20");
    // c-fat: the paper's canonical always-splits family (Table III shows
    // every split has exactly 2 components)
    let d = datasets::dataset("c-fat500-5").unwrap();
    let row = tables::table3_row(&d);
    assert!(
        row.disabled_timed_out || row.nodes_enabled <= row.nodes_disabled,
        "{}: component branching did not reduce tree nodes ({} vs {})",
        d.name,
        row.nodes_enabled,
        row.nodes_disabled
    );
    assert!(row.component_branches > 0, "c-fat must branch on components");
    // paper: c-fat splits are all 2-component
    let max_comps = row.histogram.keys().max().copied().unwrap_or(0);
    assert!(max_comps >= 2);
}

#[test]
fn fig4_fractions_are_normalized() {
    std::env::set_var("CAVC_TIMEOUT_S", "20");
    let d = datasets::dataset("power-eris1176").unwrap();
    let row = tables::fig4_row(&d);
    let sum: f64 = row.fractions.iter().sum();
    assert!((sum - 1.0).abs() < 1e-6, "fractions sum to {sum}");
}

#[test]
fn accelerated_root_split_agrees_with_cpu_when_available() {
    use cavc::runtime::{Accelerator, ArtifactSet};
    let set = ArtifactSet::default_location();
    if !set.complete() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let acc = Accelerator::with_artifacts(set).unwrap();
    let d = datasets::dataset("SYNTHETIC").unwrap();
    let g = d.build();
    // root split of the reduced residual graph, as the solve pipeline does
    let p = cavc::prep::prepare(&g, &cavc::prep::PrepConfig::default(), None);
    let sets = acc.component_split(&p.residual.graph).unwrap();
    let cpu = cavc::graph::components::vertex_sets(&p.residual.graph);
    let mut a: Vec<usize> = sets.iter().map(|s| s.len()).collect();
    let mut b: Vec<usize> = cpu.iter().map(|s| s.len()).filter(|&l| l > 0).collect();
    a.sort_unstable();
    b.sort_unstable();
    // accel path returns every vertex incl. isolated; compare non-trivial
    let a: Vec<usize> = a.into_iter().filter(|&l| l > 1).collect();
    let b: Vec<usize> = b.into_iter().filter(|&l| l > 1).collect();
    assert_eq!(a, b);
}
