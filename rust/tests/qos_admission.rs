//! Admission & QoS tests for the resident service: bounded-queue
//! backpressure, lane scheduling, tenant quotas, the delta-mode
//! cancel-latency regression, and the differential guarantee that lane
//! scheduling never changes objectives or witnesses.

use cavc::graph::generators;
use cavc::solver::{
    oracle, JobOptions, Lane, NodeRepr, Problem, SchedulerKind, SolverConfig, SubmitError,
    TenantQuota, Termination, VcService,
};
use std::time::{Duration, Instant};

/// A dense graph whose exact MVC search runs far longer than any of
/// these tests wait (p_hat blobs are reduction-resistant).
fn long_running_graph() -> cavc::graph::Graph {
    generators::p_hat(180, 0.35, 0.85, 11)
}

/// Poll `cond` until it holds or `deadline` elapses.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t = Instant::now();
    while t.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    cond()
}

#[test]
fn full_admission_queue_rejects_try_submit_and_unblocks_blocked_submits() {
    // max_live_jobs(1) holds everything behind the hog, so the bounded
    // queue deterministically fills.
    let svc = VcService::builder().workers(1).max_queued(2).max_live_jobs(1).build();
    let hog = svc
        .try_submit_with(
            Problem::mvc(long_running_graph()),
            JobOptions { priority: Some(Lane::Throughput), ..JobOptions::default() },
        )
        .expect("empty queue admits");
    assert!(
        wait_until(Duration::from_secs(10), || svc.stats().admission.live_jobs == 1),
        "hog must dispatch"
    );
    let g1 = generators::erdos_renyi(14, 0.2, 1);
    let g2 = generators::erdos_renyi(14, 0.2, 2);
    let q1 = svc.try_submit(Problem::mvc(g1.clone())).expect("queue slot 1");
    let q2 = svc.try_submit(Problem::mvc(g2.clone())).expect("queue slot 2");
    // the queue is at its bound: backpressure, not growth
    let err = svc.try_submit(Problem::mvc(generators::path(4))).unwrap_err();
    assert_eq!(err, SubmitError::QueueFull);
    assert!(svc.stats().admission.rejected >= 1);
    assert_eq!(svc.stats().admission.queued, 2);
    // a bounded wait expires against the still-full queue
    let t = Instant::now();
    let err = svc
        .submit_within(
            Problem::mvc(generators::path(4)),
            JobOptions::default(),
            Duration::from_millis(50),
        )
        .unwrap_err();
    assert_eq!(err, SubmitError::QueueFull);
    assert!(t.elapsed() >= Duration::from_millis(50));
    // a blocked submit parks until the hog finalizes and frees capacity
    let unblocked = std::thread::scope(|s| {
        let svc = &svc;
        let blocked = s.spawn(move || {
            svc.submit_within(
                Problem::mvc(generators::path(6)),
                JobOptions::default(),
                Duration::from_secs(30),
            )
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!blocked.is_finished(), "queue is full: the submit must block");
        hog.cancel();
        assert_eq!(hog.wait().termination, Termination::Cancelled);
        blocked.join().expect("blocked submitter thread")
    });
    let h = unblocked.expect("freed capacity admits the blocked submit");
    // everything held back by the hog now flows through in order
    assert_eq!(q1.wait().objective, oracle::mvc_size(&g1));
    assert_eq!(q2.wait().objective, oracle::mvc_size(&g2));
    assert_eq!(h.wait().termination, Termination::Complete);
    assert!(svc.stats().admission.blocked > Duration::ZERO);
}

#[test]
fn latency_lane_jobs_complete_while_a_throughput_job_branches() {
    let svc = VcService::builder().workers(2).build();
    let big = svc.submit_with(
        Problem::mvc(long_running_graph()),
        JobOptions { priority: Some(Lane::Throughput), ..JobOptions::default() },
    );
    // let the hog get past setup and saturate both workers
    std::thread::sleep(Duration::from_millis(20));
    let mut small = Vec::new();
    for seed in 0..8u64 {
        let g = generators::erdos_renyi(15, 0.2, seed);
        let opt = oracle::mvc_size(&g);
        let h = svc.submit_with(
            Problem::mvc(g),
            JobOptions { priority: Some(Lane::Latency), ..JobOptions::default() },
        );
        small.push((h, opt));
    }
    for (i, (h, opt)) in small.iter().enumerate() {
        let sol = h.wait();
        assert_eq!(sol.termination, Termination::Complete, "latency job {i}");
        assert_eq!(sol.objective, *opt, "latency job {i}");
    }
    assert!(big.try_result().is_none(), "throughput hog finished implausibly fast");
    let stats = svc.stats();
    assert_eq!(stats.admission.dispatched_latency, 8);
    assert_eq!(stats.admission.dispatched_throughput, 1);
    big.cancel();
    assert_eq!(big.wait().termination, Termination::Cancelled);
}

#[test]
fn tenant_job_quota_is_enforced_and_released() {
    let svc = VcService::builder()
        .workers(2)
        .tenant_quota(TenantQuota { max_jobs: 2, max_live_nodes: u64::MAX })
        .build();
    let tenant = |name: &str| JobOptions {
        priority: Some(Lane::Throughput),
        tenant: Some(name.into()),
        ..JobOptions::default()
    };
    let a = svc
        .try_submit_with(Problem::mvc(long_running_graph()), tenant("acme"))
        .expect("acme job 1");
    let b = svc
        .try_submit_with(Problem::mvc(long_running_graph()), tenant("acme"))
        .expect("acme job 2");
    let err =
        svc.try_submit_with(Problem::mvc(generators::path(4)), tenant("acme")).unwrap_err();
    assert_eq!(err, SubmitError::QuotaExceeded);
    assert!(svc.stats().admission.quota_rejected >= 1);
    // other tenants and untenanted jobs are unaffected
    let other = svc
        .try_submit_with(Problem::mvc(generators::path(5)), tenant("globex"))
        .expect("other tenant admits");
    let free = svc.try_submit(Problem::mvc(generators::path(6))).expect("untenanted admits");
    // finalizing a job releases its quota slot (the release can trail
    // `wait` by an instant, hence the bounded blocking submit)
    a.cancel();
    assert_eq!(a.wait().termination, Termination::Cancelled);
    let c = svc
        .submit_within(Problem::mvc(generators::path(7)), tenant("acme"), Duration::from_secs(30))
        .expect("slot freed after finalization");
    b.cancel();
    b.wait();
    assert_eq!(c.wait().termination, Termination::Complete);
    other.wait();
    free.wait();
}

#[test]
fn quota_rejection_wins_over_queue_full_when_both_apply() {
    // Regression: admission used to report QueueFull to a tenant at
    // quota whenever the queue was *also* full (the branch tested
    // `over_quota && !full`), so the tenant's backoff targeted the
    // wrong resource. The documented shed order is MemoryPressure >
    // QuotaExceeded > QueueFull.
    let svc = VcService::builder()
        .workers(1)
        .max_queued(1)
        .max_live_jobs(1)
        .tenant_quota(TenantQuota { max_jobs: 1, max_live_nodes: u64::MAX })
        .build();
    let tenant = |name: &str| JobOptions {
        priority: Some(Lane::Throughput),
        tenant: Some(name.into()),
        ..JobOptions::default()
    };
    let hog = svc
        .try_submit_with(Problem::mvc(long_running_graph()), tenant("acme"))
        .expect("empty service admits the hog");
    assert!(
        wait_until(Duration::from_secs(10), || svc.stats().admission.live_jobs == 1),
        "hog must dispatch so the queue slot frees"
    );
    // Fill the single queue slot with an untenanted job; max_live_jobs(1)
    // keeps it parked behind the hog.
    let queued = svc.try_submit(Problem::mvc(generators::path(4))).expect("queue slot");
    assert_eq!(svc.stats().admission.queued, 1);
    // Both shed conditions now hold for "acme": the queue is at its
    // bound AND the tenant is at its job quota. The quota verdict wins.
    let err = svc.try_submit_with(Problem::mvc(generators::path(5)), tenant("acme")).unwrap_err();
    assert_eq!(err, SubmitError::QuotaExceeded, "quota beats queue-full in the shed order");
    assert!(svc.stats().admission.quota_rejected >= 1);
    // An untenanted submit against the same full queue still sees
    // queue-full — the fix reorders the verdicts, it does not widen the
    // quota check.
    let err = svc.try_submit(Problem::mvc(generators::path(6))).unwrap_err();
    assert_eq!(err, SubmitError::QueueFull);
    hog.cancel();
    assert_eq!(hog.wait().termination, Termination::Cancelled);
    queued.wait();
}

#[test]
fn tenant_live_node_quota_blocks_admission_while_a_job_runs() {
    let svc = VcService::builder()
        .workers(1)
        .tenant_quota(TenantQuota { max_jobs: 100, max_live_nodes: 1 })
        .build();
    let opts = JobOptions {
        priority: Some(Lane::Throughput),
        tenant: Some("acme".into()),
        ..JobOptions::default()
    };
    let big = svc
        .try_submit_with(Problem::mvc(long_running_graph()), opts.clone())
        .expect("first job");
    // The job's setup item is charged against the tenant at admission
    // and stays >= 1 while the search runs: the node quota is saturated.
    let err = svc.try_submit_with(Problem::mvc(generators::path(4)), opts.clone()).unwrap_err();
    assert_eq!(err, SubmitError::QuotaExceeded);
    big.cancel();
    assert_eq!(big.wait().termination, Termination::Cancelled);
    // every node charge is released by the time the outcome publishes
    let next = svc
        .submit_within(Problem::mvc(generators::path(5)), opts, Duration::from_secs(30))
        .expect("node charges released");
    assert_eq!(next.wait().termination, Termination::Complete);
}

#[test]
fn cancel_mid_descent_is_bounded_in_delta_mode_on_both_schedulers() {
    // Regression (cancel/deadline latency): the delta representation
    // descends in place without popping, so a 1-worker pool used to
    // observe the stop flag only at pop time — cancelling mid-descent
    // waited for the whole subtree. The in-descent stop poll (every 64
    // in-place nodes) bounds it.
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(1).scheduler(sched).build();
        let h = svc.submit_with(
            Problem::mvc(long_running_graph()),
            JobOptions {
                config: Some(SolverConfig::proposed().with_node_repr(NodeRepr::Delta)),
                priority: Some(Lane::Throughput),
                ..JobOptions::default()
            },
        );
        // let the single worker get deep into the in-place descent
        std::thread::sleep(Duration::from_millis(30));
        assert!(
            h.try_result().is_none(),
            "{}: dense search cannot finish in 30ms",
            sched.name()
        );
        let t = Instant::now();
        h.cancel();
        let sol = h.wait();
        assert_eq!(sol.termination, Termination::Cancelled, "{}", sched.name());
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "{}: cancel-to-wait took {:?} — in-descent stop poll broken",
            sched.name(),
            t.elapsed()
        );
    }
}

#[test]
fn lane_scheduling_never_changes_objectives_or_witnesses() {
    // Lanes change only *when* work is picked up, never what is
    // computed: mixed-priority submissions must produce oracle-exact
    // objectives and verified witnesses on both schedulers and both
    // node representations.
    let lanes = [None, Some(Lane::Latency), Some(Lane::Throughput)];
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        for repr in [NodeRepr::Owned, NodeRepr::Delta] {
            let svc = VcService::builder().workers(3).scheduler(sched).build();
            let mut handles = Vec::new();
            for seed in 0..6u64 {
                let g = generators::erdos_renyi(18, 0.22, seed);
                let opt = oracle::mvc_size(&g);
                let opts = JobOptions {
                    config: Some(SolverConfig::proposed().with_node_repr(repr)),
                    extract_witness: true,
                    priority: lanes[seed as usize % lanes.len()],
                    ..JobOptions::default()
                };
                handles.push((seed, g.clone(), opt, svc.submit_with(Problem::mvc(g), opts)));
            }
            for (seed, g, opt, h) in handles {
                let sol = h.wait();
                let tag = format!("{} {} seed {seed}", sched.name(), repr.name());
                assert_eq!(sol.objective, opt, "{tag}: lane changed the objective");
                assert_eq!(sol.termination, Termination::Complete, "{tag}");
                let w = sol.witness.as_ref().expect("witness");
                assert_eq!(w.len() as u32, opt, "{tag}: witness length");
                assert!(g.is_vertex_cover(w), "{tag}: witness invalid");
                assert_eq!(sol.witness_verified, Some(true), "{tag}");
            }
        }
    }
}
