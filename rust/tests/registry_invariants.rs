//! Stress tests for the component branch registry under real concurrent
//! solver runs: counters must drain, totals must be exact, and the
//! last-descendant cascade must fire exactly once per split — across
//! hundreds of racy repetitions.

use cavc::graph::{generators, Graph};
use cavc::solver::registry::{cas_min, Registry, NONE};
use cavc::solver::{oracle, solve_mvc, SolverConfig};
use cavc::util::SplitMix64;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

/// Deeply nested splits driven directly against the registry from many
/// threads: a random split tree is generated, every leaf is "solved" by a
/// worker pool in random order, and the root total must equal the sum of
/// leaf minima exactly once.
#[test]
fn randomized_nested_split_trees() {
    for trial in 0..30u64 {
        let mut rng = SplitMix64::new(trial);
        let reg = Registry::new(false);
        // Build a random nested split structure:
        // each parent has 2-4 children; children may nest another split.
        struct Leaf {
            ctx: u32,
            answer: u32,
        }
        let mut leaves = Vec::new();
        let mut expected_root = 0u32;

        fn build(
            reg: &Registry,
            rng: &mut SplitMix64,
            ancestor: u32,
            depth: usize,
            leaves: &mut Vec<Leaf>,
        ) -> u32 {
            // returns the exact total this split contributes
            let sum0 = rng.range(0, 3) as u32;
            let p = reg.new_parent(sum0, ancestor);
            let kids = rng.range(2, 4);
            let mut total = sum0;
            for _ in 0..kids {
                let answer = rng.range(1, 6) as u32;
                let best0 = answer + rng.range(0, 3) as u32; // achievable init
                let c = reg.new_child(p, best0, best0);
                if depth < 2 && rng.chance(0.4) {
                    // nested split inside this component: its total becomes
                    // the component's best (assume it improves on best0)
                    let nested_total = build(reg, rng, c, depth + 1, leaves);
                    total += nested_total.min(best0);
                } else {
                    leaves.push(Leaf { ctx: c, answer });
                    total += answer.min(best0);
                }
            }
            let mut sink = |_t: u32| {};
            reg.finish_scan(p, &mut sink);
            total
        }

        expected_root += build(&reg, &mut rng, NONE, 0, &mut leaves);
        rng.shuffle(&mut leaves);

        let root_val = AtomicU32::new(u32::MAX);
        let fired = AtomicUsize::new(0);
        let chunk = leaves.len().div_ceil(4).max(1);
        std::thread::scope(|s| {
            for batch in leaves.chunks(chunk) {
                let reg = &reg;
                let root_val = &root_val;
                let fired = &fired;
                s.spawn(move || {
                    for leaf in batch {
                        let mut on_root = |t: u32| {
                            fired.fetch_add(1, Ordering::SeqCst);
                            cas_min(root_val, t);
                        };
                        reg.report_solution(leaf.ctx, leaf.answer, &mut on_root);
                        reg.complete_node(leaf.ctx, &mut on_root);
                    }
                });
            }
        });
        assert_eq!(fired.load(Ordering::SeqCst), 1, "trial {trial}: cascade fired != once");
        assert_eq!(
            root_val.load(Ordering::SeqCst),
            expected_root,
            "trial {trial}: wrong root total"
        );
        reg.assert_drained();
    }
}

/// Repeated parallel solves on splitting graphs: results must be
/// deterministic (equal to the oracle) regardless of scheduling races.
#[test]
fn parallel_solves_are_schedule_independent() {
    let graphs: Vec<Graph> = vec![
        generators::union_of_random(5, 4, 8, 0.3, 1),
        Graph::disjoint_union(&[
            generators::petersen(),
            generators::generalized_petersen(8, 2),
            generators::cycle(11),
        ]),
        generators::banded(60, 2, 0.3, 10, 2),
    ];
    for (gi, g) in graphs.iter().enumerate() {
        let opt = if g.num_vertices() <= 64 { Some(oracle::mvc_size(g)) } else { None };
        let mut answers = std::collections::HashSet::new();
        for rep in 0..12 {
            let cfg = SolverConfig::proposed().with_workers(1 + rep % 6);
            let r = solve_mvc(g, &cfg);
            answers.insert(r.best);
        }
        assert_eq!(answers.len(), 1, "graph {gi}: nondeterministic answers {answers:?}");
        if let Some(opt) = opt {
            assert!(answers.contains(&opt), "graph {gi}: wrong answer");
        }
    }
}

/// The registry's Best/Limit split keeps PVC totals achievable: a PVC
/// search must never claim a cover smaller than the true optimum.
#[test]
fn pvc_never_claims_below_optimum() {
    let mut rng = SplitMix64::new(0x9E);
    for trial in 0..30 {
        let parts = rng.range(2, 5);
        let g = generators::union_of_random(parts, 3, 7, 0.35, rng.next_u64());
        if g.num_vertices() > 64 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        for k in [opt, opt + 1, opt + 3] {
            let r = cavc::solver::solve_pvc(&g, k, &SolverConfig::proposed());
            assert!(r.found, "trial {trial} k={k}");
            let sz = r.size.unwrap();
            assert!(sz >= opt, "trial {trial}: claimed {sz} < optimum {opt}");
            assert!(sz <= k, "trial {trial}: claimed {sz} > k {k}");
        }
    }
}
