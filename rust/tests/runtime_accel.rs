//! Integration: the PJRT runtime executes the AOT artifacts and agrees
//! with the native CPU implementations. Requires `make artifacts`; every
//! test is skipped (with a message) when the artifacts are absent.

use cavc::graph::{components, generators, metrics, Graph};
use cavc::runtime::{Accelerator, ArtifactSet};

fn accel() -> Option<Accelerator> {
    let set = ArtifactSet::default_location();
    if !set.complete() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Accelerator::with_artifacts(set).expect("pjrt cpu client"))
}

/// Labels must define the same partition (accel labels are min-vertex-id
/// per component; CPU labels are discovery-ordered).
fn same_partition(a: &[u32], b: &[u32]) -> bool {
    use std::collections::HashMap;
    let mut fwd: HashMap<u32, u32> = HashMap::new();
    let mut bwd: HashMap<u32, u32> = HashMap::new();
    for (&x, &y) in a.iter().zip(b) {
        if *fwd.entry(x).or_insert(y) != y || *bwd.entry(y).or_insert(x) != x {
            return false;
        }
    }
    true
}

#[test]
fn components_match_cpu_on_random_graphs() {
    let Some(acc) = accel() else { return };
    for seed in 0..6 {
        let g = generators::erdos_renyi(100, 0.02, seed);
        let xla = acc.connected_components(&g).expect("xla components");
        let (cpu, _) = components::labels(&g);
        assert!(same_partition(&xla, &cpu), "seed {seed}");
    }
}

#[test]
fn components_match_on_multi_component_suite() {
    let Some(acc) = accel() else { return };
    let g = generators::union_of_random(12, 4, 9, 0.3, 7);
    let xla = acc.connected_components(&g).expect("xla components");
    let (cpu, k) = components::labels(&g);
    assert_eq!(k, 12);
    assert!(same_partition(&xla, &cpu));
}

#[test]
fn components_all_size_classes() {
    let Some(acc) = accel() else { return };
    for n in [100usize, 200, 500, 1000] {
        let g = generators::banded(n, 1, 0.1, 20, n as u64);
        let xla = acc.connected_components(&g).expect("xla components");
        let (cpu, _) = components::labels(&g);
        assert!(same_partition(&xla, &cpu), "n={n}");
    }
}

#[test]
fn bfs_reach_matches_cpu() {
    let Some(acc) = accel() else { return };
    let g = Graph::disjoint_union(&[
        generators::random_tree(60, 3),
        generators::cycle(40),
        generators::clique(10),
    ]);
    for source in [0u32, 65, 105] {
        let xla = acc.bfs_reach(&g, source).expect("xla bfs");
        let cpu = components::bfs_reach(&g, source);
        for v in 0..g.num_vertices() {
            assert_eq!(xla[v], cpu.get(v), "source {source} vertex {v}");
        }
    }
}

#[test]
fn triangle_census_matches_cpu() {
    let Some(acc) = accel() else { return };
    for seed in 0..4 {
        let g = generators::erdos_renyi(90, 0.08, seed);
        let xla = acc.triangle_census(&g).expect("xla triangles");
        let cpu = metrics::triangles_per_vertex(&g);
        assert_eq!(xla, cpu, "seed {seed}");
    }
}

#[test]
fn component_split_falls_back_beyond_max_class() {
    let Some(acc) = accel() else { return };
    let g = generators::banded(2000, 1, 0.05, 10, 5); // > 1024 vertices
    let sets = acc.component_split(&g).expect("fallback split");
    let total: usize = sets.iter().map(|s| s.len()).sum();
    assert_eq!(total, g.num_vertices());
}

#[test]
fn oversize_direct_call_errors() {
    let Some(acc) = accel() else { return };
    let g = generators::path(1500);
    assert!(acc.connected_components(&g).is_err());
}
