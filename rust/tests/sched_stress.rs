//! Concurrency stress tests for the lock-free scheduling runtime: the
//! Chase–Lev deques and the injector are hammered from many threads with
//! totals reconciled against the per-worker counters, and the epoch
//! termination detector must provably drain deep imbalanced trees at
//! 1, 4, and 16 workers (more workers than this machine has cores, so
//! preemption-heavy interleavings get exercised too).

use cavc::solver::sched::deque::{ChaseLev, Steal};
use cavc::solver::sched::injector::Injector;
use cavc::solver::sched::{
    IdleOutcome, Scheduler, SchedulerKind, ShardedScheduler, WorkStealScheduler, WorkerCounters,
    WorkerHandle,
};
use std::sync::atomic::{AtomicU64, Ordering};

/// Leaves of the imbalanced recurrence: f(0) = 1, f(x) = f(x-1) + f(x/2)
/// — one heavy child and one light child per node, so static partitions
/// starve while work stealing keeps everyone busy.
fn expected_leaves(x: u64) -> u64 {
    fn go(x: u64, memo: &mut std::collections::HashMap<u64, u64>) -> u64 {
        if x == 0 {
            return 1;
        }
        if let Some(&v) = memo.get(&x) {
            return v;
        }
        let v = go(x - 1, memo) + go(x / 2, memo);
        memo.insert(x, v);
        v
    }
    go(x, &mut std::collections::HashMap::new())
}

/// Drive the imbalanced-tree workload through a scheduler; returns the
/// leaf count and each worker's counters.
fn drain_tree<S: Scheduler<u64>>(sched: &S, workers: usize) -> (u64, Vec<WorkerCounters>) {
    let leaves = AtomicU64::new(0);
    let mut counters = vec![WorkerCounters::default(); workers];
    std::thread::scope(|scope| {
        let joins: Vec<_> = (0..workers)
            .map(|w| {
                let leaves = &leaves;
                scope.spawn(move || {
                    let mut h = sched.handle(w);
                    loop {
                        match h.pop() {
                            Some(0) => {
                                leaves.fetch_add(1, Ordering::Relaxed);
                                h.on_node_done();
                            }
                            Some(x) => {
                                h.push(x - 1); // heavy sub-tree
                                h.push(x / 2); // light sub-tree
                                h.on_node_done();
                            }
                            None => {
                                if h.idle_step() == IdleOutcome::Finished {
                                    break;
                                }
                            }
                        }
                    }
                    h.counters()
                })
            })
            .collect();
        for (w, j) in joins.into_iter().enumerate() {
            counters[w] = j.join().unwrap();
        }
    });
    (leaves.load(Ordering::Relaxed), counters)
}

#[test]
fn termination_drains_deep_imbalanced_tree() {
    let root = 100u64;
    let want = expected_leaves(root);
    assert!(want > 50_000, "workload too small to stress anything: {want}");
    for workers in [1usize, 4, 16] {
        let sched: WorkStealScheduler<u64> = WorkStealScheduler::new(workers, true, 64);
        sched.inject(root);
        let (leaves, counters) = drain_tree(&sched, workers);
        assert_eq!(leaves, want, "workers={workers}: leaves lost or duplicated");
        // Counter reconciliation: every acquisition is a push or the root.
        let acquired: u64 = counters.iter().map(|c| c.acquired()).sum();
        let pushed: u64 = counters.iter().map(|c| c.pushes).sum();
        assert_eq!(acquired, pushed + 1, "workers={workers}: queue traffic leaked");
        if workers > 1 {
            // With this imbalance something must have been stolen or
            // pulled from the injector by a non-owner.
            let steals: u64 = counters.iter().map(|c| c.steals).sum();
            let shared: u64 = counters.iter().map(|c| c.shared_pops).sum();
            let moved = steals + shared;
            assert!(moved >= 1, "workers={workers}: no load balancing happened");
        }
    }
}

#[test]
fn termination_matches_between_schedulers() {
    let root = 30u64;
    let want = expected_leaves(root);
    for workers in [1usize, 4] {
        let ws: WorkStealScheduler<u64> = WorkStealScheduler::new(workers, true, 64);
        ws.inject(root);
        let (a, _) = drain_tree(&ws, workers);
        let sh: ShardedScheduler<u64> = ShardedScheduler::new(workers, true, 64);
        sh.inject(root);
        let (b, _) = drain_tree(&sh, workers);
        assert_eq!(a, want, "worksteal workers={workers}");
        assert_eq!(b, want, "sharded workers={workers}");
    }
}

#[test]
fn repeated_racy_drains_are_exact() {
    // Many short racy runs catch interleavings a single long run misses.
    let root = 18u64;
    let want = expected_leaves(root);
    for trial in 0..40 {
        let sched: WorkStealScheduler<u64> = WorkStealScheduler::new(8, true, 16);
        sched.inject(root);
        let (leaves, _) = drain_tree(&sched, 8);
        assert_eq!(leaves, want, "trial {trial}");
    }
}

#[test]
fn deque_hammer_with_heavy_contention() {
    // One owner against 7 thieves on a single deque, items carrying a
    // checksum so duplication and loss are both detectable.
    const ITEMS: u64 = 50_000;
    let d: ChaseLev<u64> = ChaseLev::with_capacity(8);
    let consumed = AtomicU64::new(0);
    let checksum = AtomicU64::new(0);
    std::thread::scope(|s| {
        for _ in 0..7 {
            let d = &d;
            let consumed = &consumed;
            let checksum = &checksum;
            s.spawn(move || loop {
                match d.steal() {
                    Steal::Taken(x) => {
                        checksum.fetch_add(x, Ordering::Relaxed);
                        consumed.fetch_add(1, Ordering::Relaxed);
                    }
                    Steal::Retry => std::hint::spin_loop(),
                    Steal::Empty => {
                        if consumed.load(Ordering::Relaxed) == ITEMS {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            });
        }
        let d = &d;
        let consumed = &consumed;
        let checksum = &checksum;
        s.spawn(move || {
            for i in 1..=ITEMS {
                unsafe { d.push(i) };
                // Pop some back so the owner/thief race on the last item
                // is exercised constantly.
                if i % 2 == 0 {
                    if let Some(x) = unsafe { d.pop() } {
                        checksum.fetch_add(x, Ordering::Relaxed);
                        consumed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            while let Some(x) = unsafe { d.pop() } {
                checksum.fetch_add(x, Ordering::Relaxed);
                consumed.fetch_add(1, Ordering::Relaxed);
            }
        });
    });
    assert_eq!(consumed.load(Ordering::Relaxed), ITEMS);
    assert_eq!(checksum.load(Ordering::Relaxed), ITEMS * (ITEMS + 1) / 2);
}

#[test]
fn injector_hammer_mpmc() {
    const PRODUCERS: u64 = 8;
    const PER: u64 = 10_000;
    let q: Injector<u64> = Injector::new();
    let consumed = AtomicU64::new(0);
    let checksum = AtomicU64::new(0);
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let q = &q;
            s.spawn(move || {
                for i in 0..PER {
                    q.push(p * PER + i + 1);
                }
            });
        }
        for _ in 0..8 {
            let q = &q;
            let consumed = &consumed;
            let checksum = &checksum;
            s.spawn(move || loop {
                match q.pop() {
                    Some(x) => {
                        checksum.fetch_add(x, Ordering::Relaxed);
                        consumed.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        if consumed.load(Ordering::Relaxed) == PRODUCERS * PER {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    let n = PRODUCERS * PER;
    assert_eq!(consumed.load(Ordering::Relaxed), n);
    assert_eq!(checksum.load(Ordering::Relaxed), n * (n + 1) / 2);
    assert!(q.is_empty());
}

#[test]
fn boxed_payloads_never_double_free() {
    // Same racy drain but with heap payloads: a duplicated or leaked
    // node corrupts the count (and crashes under a hardened allocator).
    let sched: WorkStealScheduler<Box<u64>> = WorkStealScheduler::new(8, true, 8);
    sched.inject(Box::new(16));
    let leaves = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for w in 0..8 {
            let sched = &sched;
            let leaves = &leaves;
            scope.spawn(move || {
                let mut h = sched.handle(w);
                loop {
                    match h.pop() {
                        Some(x) if *x == 0 => {
                            leaves.fetch_add(1, Ordering::Relaxed);
                            h.on_node_done();
                        }
                        Some(x) => {
                            h.push(Box::new(*x - 1));
                            h.push(Box::new(*x / 2));
                            h.on_node_done();
                        }
                        None => {
                            if h.idle_step() == IdleOutcome::Finished {
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    assert_eq!(leaves.load(Ordering::Relaxed), expected_leaves(16));
}

#[test]
fn scheduler_kind_parse_roundtrip() {
    assert_eq!(SchedulerKind::parse("steal"), Some(SchedulerKind::WorkSteal));
    assert_eq!(SchedulerKind::parse("sharded"), Some(SchedulerKind::Sharded));
    assert_eq!(SchedulerKind::parse("chase-lev"), Some(SchedulerKind::WorkSteal));
    assert_eq!(SchedulerKind::parse("nope"), None);
    for k in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        assert_eq!(SchedulerKind::parse(k.name()), Some(k));
    }
}
