//! Job-lifecycle tests for the resident solver service: cancellation,
//! per-job deadlines, concurrent submitters, and small jobs making
//! progress while a large job saturates the pool.

use cavc::graph::generators;
use cavc::solver::{oracle, JobOptions, Problem, SchedulerKind, Termination, VcService};
use std::time::{Duration, Instant};

/// A dense graph whose exact MVC search runs far longer than any of
/// these tests wait (p_hat blobs are reduction-resistant).
fn long_running_graph() -> cavc::graph::Graph {
    generators::p_hat(180, 0.35, 0.85, 11)
}

#[test]
fn cancellation_stops_a_running_job_and_pool_stays_usable() {
    let svc = VcService::builder().workers(2).build();
    let big = svc.submit(Problem::mvc(long_running_graph()));
    // let it get past setup and into real branching
    std::thread::sleep(Duration::from_millis(30));
    assert!(big.try_result().is_none(), "dense search cannot finish in 30ms");
    big.cancel();
    let t = Instant::now();
    let sol = big.wait();
    assert_eq!(sol.termination, Termination::Cancelled);
    // queued nodes drain at pop speed — seconds would mean cancel leaks
    assert!(t.elapsed() < Duration::from_secs(20), "cancel drain took {:?}", t.elapsed());
    // the pool must still serve fresh jobs correctly
    let g = generators::erdos_renyi(16, 0.2, 5);
    let opt = oracle::mvc_size(&g);
    assert_eq!(svc.solve(Problem::mvc(g)).objective, opt);
}

#[test]
fn cancelling_a_finished_job_is_a_noop() {
    let svc = VcService::builder().workers(1).build();
    let g = generators::path(6);
    let h = svc.submit(Problem::mvc(g));
    let first = h.wait();
    assert_eq!(first.termination, Termination::Complete);
    h.cancel(); // after completion: must not rewrite the outcome
    let again = h.wait();
    assert_eq!(again.termination, Termination::Complete);
    assert_eq!(again.objective, first.objective);
}

#[test]
fn per_job_deadline_expires_and_reports_a_bound() {
    let svc = VcService::builder().workers(2).build();
    let h = svc.submit_with(
        Problem::mvc(long_running_graph()),
        JobOptions { timeout: Some(Duration::from_millis(25)), ..JobOptions::default() },
    );
    let sol = h.wait();
    assert_eq!(sol.termination, Termination::DeadlineExpired);
    assert!(sol.timed_out());
    // the objective is still a sound upper bound (greedy at worst)
    assert!(sol.objective >= 1);
    assert!(sol.objective <= 180);
}

#[test]
fn deadline_on_pvc_reports_unknown_infeasible() {
    let svc = VcService::builder().workers(2).build();
    // k=1 on a dense graph: provably infeasible, but the proof needs a
    // search the deadline cuts short — found must come back false.
    let h = svc.submit_with(
        Problem::pvc(long_running_graph(), 1),
        JobOptions { timeout: Some(Duration::from_millis(25)), ..JobOptions::default() },
    );
    let sol = h.wait();
    assert!(!sol.feasible);
}

#[test]
fn deadlines_do_not_leak_across_jobs() {
    // A deadline on job A must not stop job B sharing the pool.
    let svc = VcService::builder().workers(3).build();
    let bounded = svc.submit_with(
        Problem::mvc(long_running_graph()),
        JobOptions { timeout: Some(Duration::from_millis(20)), ..JobOptions::default() },
    );
    let g = generators::union_of_random(3, 3, 6, 0.3, 9);
    let opt = oracle::mvc_size(&g);
    let free = svc.submit(Problem::mvc(g));
    assert_eq!(bounded.wait().termination, Termination::DeadlineExpired);
    let sol = free.wait();
    assert_eq!(sol.termination, Termination::Complete);
    assert_eq!(sol.objective, opt);
}

#[test]
fn small_jobs_complete_while_a_large_job_is_branching() {
    // The headline property: one large graph keeps branching while many
    // small graphs stream through the same pool.
    let svc = VcService::builder().workers(2).build();
    let big = svc.submit(Problem::mvc(long_running_graph()));
    let mut pending: Vec<(cavc::solver::JobHandle, u32)> = Vec::new();
    for seed in 0..12u64 {
        let g = generators::erdos_renyi(15, 0.2, seed);
        let opt = oracle::mvc_size(&g);
        pending.push((svc.submit(Problem::mvc(g)), opt));
    }
    for (i, (h, opt)) in pending.iter().enumerate() {
        let sol = h.wait();
        assert_eq!(sol.termination, Termination::Complete, "small job {i}");
        assert_eq!(sol.objective, *opt, "small job {i}");
    }
    // the big job is still running — the small jobs did not wait for it
    assert!(big.try_result().is_none(), "dense search finished implausibly fast");
    big.cancel();
    assert_eq!(big.wait().termination, Termination::Cancelled);
}

#[test]
fn double_cancel_is_idempotent_and_waiters_agree() {
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(2).scheduler(sched).build();
        let h = svc.submit(Problem::mvc(long_running_graph()));
        h.cancel();
        h.cancel(); // second cancel must be a harmless no-op
        let h2 = h.clone();
        let other = std::thread::spawn(move || h2.wait());
        let a = h.wait();
        let b = other.join().expect("waiter thread");
        assert_eq!(a.termination, Termination::Cancelled, "{}", sched.name());
        // every waiter observes the one published outcome
        assert_eq!(b.termination, a.termination, "{}", sched.name());
        assert_eq!(b.objective, a.objective, "{}", sched.name());
        h.cancel(); // cancel after the outcome: still a no-op
        assert_eq!(h.wait().objective, a.objective, "{}", sched.name());
    }
}

#[test]
fn cancel_racing_completion_publishes_exactly_one_outcome() {
    // Cancel small jobs at the instant they may be finalizing: whichever
    // side wins, `wait` must settle on one immutable outcome and a
    // Complete answer must still be exact.
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(2).scheduler(sched).build();
        for seed in 0..20u64 {
            let g = generators::erdos_renyi(14, 0.25, seed);
            let opt = oracle::mvc_size(&g);
            let h = svc.submit(Problem::mvc(g));
            h.cancel();
            let first = h.wait();
            match first.termination {
                Termination::Complete => {
                    assert_eq!(first.objective, opt, "{} seed {seed}", sched.name())
                }
                Termination::Cancelled => {}
                t => panic!("{} seed {seed}: unexpected termination {t:?}", sched.name()),
            }
            let again = h.wait();
            assert_eq!(again.termination, first.termination, "{} seed {seed}", sched.name());
            assert_eq!(again.objective, first.objective, "{} seed {seed}", sched.name());
        }
    }
}

#[test]
fn deadline_racing_finalization_is_consistent() {
    // Deadlines short enough to fire *during* setup/finalization of a
    // small job: the outcome must be one of Complete/DeadlineExpired,
    // published once, with Complete answers still exact.
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(2).scheduler(sched).build();
        for (i, micros) in [0u64, 50, 200, 500, 1_000, 2_000, 5_000].into_iter().enumerate() {
            let g = generators::erdos_renyi(14, 0.25, i as u64);
            let opt = oracle::mvc_size(&g);
            let h = svc.submit_with(
                Problem::mvc(g),
                JobOptions {
                    timeout: Some(Duration::from_micros(micros)),
                    ..JobOptions::default()
                },
            );
            let first = h.wait();
            match first.termination {
                Termination::Complete => {
                    assert_eq!(first.objective, opt, "{} {micros}us", sched.name())
                }
                Termination::DeadlineExpired => {
                    // anytime bound: sound (greedy at worst), never junk
                    assert!(first.objective <= 14, "{} {micros}us", sched.name());
                }
                t => panic!("{} {micros}us: unexpected termination {t:?}", sched.name()),
            }
            let again = h.wait();
            assert_eq!(again.termination, first.termination, "{} {micros}us", sched.name());
            assert_eq!(again.objective, first.objective, "{} {micros}us", sched.name());
        }
    }
}

#[test]
fn concurrent_submitters_share_one_service() {
    let svc = VcService::builder().workers(4).build();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let svc = &svc;
            s.spawn(move || {
                for i in 0..6u64 {
                    let seed = t * 100 + i;
                    let g = generators::erdos_renyi(14, 0.22, seed);
                    let opt = oracle::mvc_size(&g);
                    let sol = svc.solve(Problem::mvc(g));
                    assert_eq!(sol.objective, opt, "submitter {t} job {i}");
                }
            });
        }
    });
}

#[test]
fn both_resident_runtimes_run_the_lifecycle() {
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        let svc = VcService::builder().workers(2).scheduler(sched).build();
        // normal job
        let g = generators::union_of_random(3, 3, 6, 0.3, 17);
        let opt = oracle::mvc_size(&g);
        assert_eq!(svc.solve(Problem::mvc(g)).objective, opt, "{}", sched.name());
        // cancelled job
        let doomed = svc.submit(Problem::mvc(long_running_graph()));
        doomed.cancel();
        assert_eq!(doomed.wait().termination, Termination::Cancelled, "{}", sched.name());
        // deadline job
        let bounded = svc.submit_with(
            Problem::mvc(long_running_graph()),
            JobOptions { timeout: Some(Duration::from_millis(20)), ..JobOptions::default() },
        );
        assert_eq!(
            bounded.wait().termination,
            Termination::DeadlineExpired,
            "{}",
            sched.name()
        );
    }
}
