//! Property tests: every solver variant computes the same MVC as the
//! brute-force oracle, across graph families, dtypes, worker counts, and
//! optimization toggles. This is the repo's primary correctness gate.

use cavc::graph::{generators, Graph};
use cavc::solver::{oracle, solve_mvc, solve_pvc, SolverConfig};
use cavc::util::SplitMix64;

fn variants() -> Vec<SolverConfig> {
    vec![
        SolverConfig::proposed(),
        SolverConfig::prior_work(),
        SolverConfig::no_load_balance(),
        SolverConfig::sequential(),
    ]
}

fn assert_all_agree(g: &Graph, tag: &str) {
    let opt = oracle::mvc_size(g);
    for cfg in variants() {
        let r = solve_mvc(g, &cfg);
        assert!(!r.timed_out, "{tag}: {} timed out", cfg.variant.name());
        assert_eq!(r.best, opt, "{tag}: {} disagrees with oracle", cfg.variant.name());
    }
}

/// A pool of random graphs spanning the families the engine must handle:
/// sparse/dense ER, unions (splits), reduction-proof regulars, stars,
/// trees, cycles, cliques, bipartite.
fn random_graph(rng: &mut SplitMix64) -> (Graph, String) {
    let kind = rng.index(9);
    let seed = rng.next_u64();
    match kind {
        0 => {
            let n = rng.range(6, 22);
            let p = 0.05 + rng.next_f64() * 0.3;
            (generators::erdos_renyi(n, p, seed), format!("er({n},{p:.2},{seed})"))
        }
        1 => {
            let parts = rng.range(2, 5);
            (
                generators::union_of_random(parts, 3, 7, 0.3, seed),
                format!("union({parts},{seed})"),
            )
        }
        2 => {
            let n = rng.range(5, 11);
            (generators::generalized_petersen(n, 2), format!("gp({n},2)"))
        }
        3 => {
            let n = rng.range(3, 15);
            (generators::cycle(n), format!("cycle({n})"))
        }
        4 => {
            let n = rng.range(3, 9);
            (generators::clique(n), format!("clique({n})"))
        }
        5 => {
            let n = rng.range(4, 30);
            (generators::random_tree(n, seed), format!("tree({n},{seed})"))
        }
        6 => {
            let l = rng.range(3, 8);
            let r = rng.range(3, 8);
            (generators::bipartite(l, r, 2.0, seed), format!("bip({l},{r},{seed})"))
        }
        7 => {
            let n = rng.range(10, 26);
            (generators::banded(n, 2, 0.3, 5, seed), format!("banded({n},{seed})"))
        }
        _ => {
            let n = rng.range(8, 18);
            (generators::p_hat(n, 0.2, 0.6, seed), format!("phat({n},{seed})"))
        }
    }
}

#[test]
fn equivalence_over_random_family_pool() {
    let mut rng = SplitMix64::new(0xE001u64);
    for trial in 0..60 {
        let (g, tag) = random_graph(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        assert_all_agree(&g, &format!("trial {trial}: {tag}"));
    }
}

#[test]
fn equivalence_with_varied_worker_counts() {
    let mut rng = SplitMix64::new(0xE002u64);
    for trial in 0..20 {
        let (g, tag) = random_graph(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        for workers in [1usize, 2, 3, 7] {
            let cfg = SolverConfig::proposed().with_workers(workers);
            assert_eq!(
                solve_mvc(&g, &cfg).best,
                opt,
                "trial {trial} {tag} workers={workers}"
            );
        }
    }
}

#[test]
fn equivalence_with_optimizations_toggled() {
    let mut rng = SplitMix64::new(0xE003u64);
    for trial in 0..15 {
        let (g, tag) = random_graph(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        for bits in 0..16u32 {
            let mut cfg = SolverConfig::proposed();
            cfg.component_aware = bits & 1 != 0;
            cfg.reduce_root = bits & 2 != 0;
            cfg.use_crown = bits & 2 != 0 && bits & 4 != 0;
            cfg.use_bounds = bits & 8 != 0;
            assert_eq!(
                solve_mvc(&g, &cfg).best,
                opt,
                "trial {trial} {tag} bits={bits:04b}"
            );
        }
    }
}

#[test]
fn pvc_agrees_with_oracle_boundaries() {
    let mut rng = SplitMix64::new(0xE004u64);
    for trial in 0..25 {
        let (g, tag) = random_graph(&mut rng);
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        for cfg in variants() {
            let below = solve_pvc(&g, opt.saturating_sub(1), &cfg);
            assert!(
                !below.found,
                "trial {trial} {tag} {}: found below optimum",
                cfg.variant.name()
            );
            let at = solve_pvc(&g, opt, &cfg);
            assert!(at.found, "trial {trial} {tag} {}: missed k=opt", cfg.variant.name());
            let sz = at.size.unwrap();
            assert!(sz <= opt, "trial {trial} {tag}: size {sz} > k {opt}");
        }
    }
}

#[test]
fn stats_consistency_proposed() {
    // tree_nodes > 0 whenever a search ran; histogram sums to splits
    let g = generators::union_of_random(4, 5, 9, 0.3, 99);
    let r = solve_mvc(&g, &SolverConfig::proposed());
    assert!(r.stats.tree_nodes > 0);
    let hist_total: u64 = r.stats.comp_histogram.values().sum();
    assert_eq!(hist_total, r.stats.component_branches);
}
