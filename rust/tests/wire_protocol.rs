//! Wire-protocol and server tests: the loopback differential (remote
//! answers match in-process answers on the same service, across both
//! schedulers and both node representations), concurrent clients, a
//! seeded malformed-frame fuzzer the server must survive, the mapping
//! of admission backpressure onto typed wire errors, and
//! disconnect-cancels-outstanding-jobs.

use cavc::graph::generators;
use cavc::solver::wire::{self, ErrorCode, Frame, SubmitRequest, WireErrorFrame};
use cavc::solver::{
    oracle, ClientError, JobOptions, Lane, NodeRepr, Problem, SchedulerKind, ServerConfig,
    ServerReply, SolverConfig, SubmitError, TenantQuota, Termination, VcClient, VcServer,
    VcService, WireOptions,
};
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// A dense graph whose exact MVC search runs far longer than any of
/// these tests wait.
fn long_running_graph() -> cavc::graph::Graph {
    generators::p_hat(180, 0.35, 0.85, 11)
}

/// Poll `cond` until it holds or `deadline` elapses.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t = Instant::now();
    while t.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    cond()
}

/// Bind a loopback server on an ephemeral port around `svc`.
fn serve(svc: VcService) -> VcServer {
    VcServer::bind("127.0.0.1:0", svc, ServerConfig::default()).expect("bind loopback")
}

fn addr_of(server: &VcServer) -> String {
    server.local_addr().to_string()
}

/// Deterministic fuzz source (SplitMix64).
struct SplitMix64(u64);

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// Remote answers are the same answers: for every scheduler × node
/// representation, a job solved over the wire must agree with the same
/// job submitted in-process on the *same* service instance, and the
/// wire witness must verify locally.
#[test]
fn loopback_differential_matches_in_process_on_both_scheds_and_reprs() {
    for sched in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
        for repr in [NodeRepr::Owned, NodeRepr::Delta] {
            let cfg = SolverConfig::proposed().with_node_repr(repr);
            let svc =
                VcService::builder().workers(2).scheduler(sched).config(cfg).build();
            let server = serve(svc);
            let mut client = VcClient::connect(addr_of(&server)).expect("connect");
            assert_eq!(client.version(), wire::PROTOCOL_VERSION);
            for seed in 0..4u64 {
                let g = generators::erdos_renyi(18, 0.22, seed);
                let opt = oracle::mvc_size(&g);
                let tag = format!("{} {} seed {seed}", sched.name(), repr.name());
                let local = server
                    .service()
                    .submit_with(
                        Problem::mvc(g.clone()),
                        JobOptions { extract_witness: true, ..JobOptions::default() },
                    )
                    .wait();
                let remote = client
                    .solve(
                        &Problem::mvc(g.clone()),
                        WireOptions { extract_witness: true, ..WireOptions::default() },
                    )
                    .expect("remote solve");
                assert_eq!(local.objective, opt, "{tag}: in-process objective");
                assert_eq!(remote.objective, opt, "{tag}: remote objective");
                assert_eq!(remote.termination, Termination::Complete, "{tag}");
                assert!(!remote.timed_out(), "{tag}");
                let w = remote.witness.as_ref().expect("wire witness");
                assert_eq!(w.len() as u32, opt, "{tag}: witness length");
                assert!(g.is_vertex_cover(w), "{tag}: wire witness invalid");
                assert_eq!(remote.witness_verified, Some(true), "{tag}");
            }
            // PVC decisions and MIS cross the wire too.
            let g = generators::erdos_renyi(16, 0.25, 99);
            let opt = oracle::mvc_size(&g);
            let yes = client
                .solve(&Problem::pvc(g.clone(), opt), WireOptions::default())
                .expect("pvc yes");
            assert!(yes.feasible && yes.objective <= opt);
            let no = client
                .solve(&Problem::pvc(g.clone(), opt - 1), WireOptions::default())
                .expect("pvc no");
            assert!(!no.feasible);
            let mis = client
                .solve(&Problem::mis(g.clone()), WireOptions::default())
                .expect("mis");
            assert_eq!(mis.objective, g.num_vertices() as u32 - opt);
            server.shutdown();
        }
    }
}

/// Several clients hammer one server concurrently; every reply routes
/// to the connection that asked, and all answers are oracle-exact.
#[test]
fn concurrent_clients_get_their_own_answers() {
    let server = serve(VcService::builder().workers(3).build());
    let addr = addr_of(&server);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..4u64 {
            let addr = addr.clone();
            handles.push(s.spawn(move || {
                let mut client = VcClient::connect(&addr).expect("connect");
                // Pipeline several submits per client, then collect.
                let mut jobs = Vec::new();
                for i in 0..3u64 {
                    let g = generators::erdos_renyi(16, 0.22, 17 * c + i);
                    let opt = oracle::mvc_size(&g);
                    let id = client.submit(&Problem::mvc(g), WireOptions::default()).unwrap();
                    jobs.push((id, opt));
                }
                let mut seen = 0;
                while seen < jobs.len() {
                    match client.recv().expect("reply") {
                        ServerReply::Solution(sol) => {
                            let (_, opt) = jobs
                                .iter()
                                .find(|(id, _)| *id == sol.req_id)
                                .expect("reply for a job this client submitted");
                            assert_eq!(sol.objective, *opt, "client {c} req {}", sol.req_id);
                            seen += 1;
                        }
                        other => panic!("client {c}: unexpected reply {other:?}"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
    });
    let stats = server.service().stats();
    assert_eq!(stats.admission.live_jobs, 0, "ledger clean after all clients drain");
    server.shutdown();
}

/// Seeded garbage, truncated frames, and oversized length prefixes
/// must never kill the server: after every fuzz round the service is
/// still serving and its admission ledger is clean.
#[test]
fn malformed_frame_fuzzer_leaves_the_server_serving() {
    let server = serve(VcService::builder().workers(2).build());
    let addr = addr_of(&server);
    let mut rng = SplitMix64(0xcafe_f00d);
    for round in 0..24 {
        let mut s = TcpStream::connect(&addr).expect("fuzz connect");
        let mut bytes = Vec::new();
        match round % 4 {
            // Pure garbage from byte zero (handshake never happens).
            0 => {
                for _ in 0..(rng.next() % 64 + 1) {
                    bytes.push(rng.next() as u8);
                }
            }
            // Valid hello, then garbage frames.
            1 => {
                bytes.extend_from_slice(&wire::encode_frame(&Frame::Hello {
                    magic: wire::WIRE_MAGIC,
                    version: wire::PROTOCOL_VERSION,
                }));
                for _ in 0..(rng.next() % 96 + 1) {
                    bytes.push(rng.next() as u8);
                }
            }
            // Valid hello, then a truncated frame: a plausible length
            // prefix with the connection cut mid-body.
            2 => {
                bytes.extend_from_slice(&wire::encode_frame(&Frame::Hello {
                    magic: wire::WIRE_MAGIC,
                    version: wire::PROTOCOL_VERSION,
                }));
                let claimed = (rng.next() % 4096 + 2) as u32;
                bytes.extend_from_slice(&claimed.to_le_bytes());
                bytes.push(wire::kind::SUBMIT);
                for _ in 0..(rng.next() % 16) {
                    bytes.push(rng.next() as u8);
                }
            }
            // Oversized length prefix: must be rejected before any
            // 64 MiB allocation happens.
            _ => {
                bytes.extend_from_slice(&(wire::MAX_FRAME_LEN + 1).to_le_bytes());
                bytes.push(wire::kind::SUBMIT);
            }
        }
        let _ = s.write_all(&bytes);
        let _ = s.flush();
        drop(s);
    }
    // A structured-but-wrong frame on a live session gets a typed error
    // and the session *continues*: the next (valid) submit still works.
    let mut s = TcpStream::connect(&addr).expect("connect");
    wire::write_frame(
        &mut s,
        &Frame::Hello { magic: wire::WIRE_MAGIC, version: wire::PROTOCOL_VERSION },
    )
    .unwrap();
    match wire::read_frame(&mut s).expect("hello-ack") {
        Frame::HelloAck { version } => assert_eq!(version, wire::PROTOCOL_VERSION),
        f => panic!("expected hello-ack, got {f:?}"),
    }
    // Unknown frame kind, well-formed length: recoverable.
    s.write_all(&[2, 0, 0, 0, 0x7f, 0xaa]).unwrap();
    s.flush().unwrap();
    match wire::read_frame(&mut s).expect("typed error reply") {
        Frame::Error(e) => assert_eq!(e.code, ErrorCode::Malformed),
        f => panic!("expected error frame, got {f:?}"),
    }
    let g = generators::erdos_renyi(14, 0.25, 3);
    wire::write_frame(
        &mut s,
        &Frame::Submit(SubmitRequest {
            req_id: 1,
            problem: Problem::mvc(g.clone()),
            opts: WireOptions::default(),
        }),
    )
    .unwrap();
    let sol = loop {
        match wire::read_frame(&mut s).expect("solution after recoverable error") {
            Frame::Solution(sol) => break sol,
            Frame::Error(e) => panic!("submit rejected: {e:?}"),
            _ => continue,
        }
    };
    assert_eq!(sol.objective, oracle::mvc_size(&g));
    drop(s);

    // The server survived it all: a fresh well-behaved client solves,
    // and nothing leaked into the admission ledger.
    let mut client = VcClient::connect(&addr).expect("post-fuzz connect");
    let g = generators::erdos_renyi(15, 0.25, 7);
    let sol = client.solve(&Problem::mvc(g.clone()), WireOptions::default()).expect("solve");
    assert_eq!(sol.objective, oracle::mvc_size(&g));
    assert!(
        wait_until(Duration::from_secs(10), || {
            let a = server.service().stats().admission;
            a.live_jobs == 0 && a.queued == 0
        }),
        "admission ledger must drain clean after the fuzz rounds"
    );
    server.shutdown();
}

/// Every admission shed reason crosses the wire as its typed error
/// code, and the client lifts it back to the in-process `SubmitError`.
#[test]
fn backpressure_maps_onto_typed_wire_errors() {
    // Queue-full: one worker, a one-slot queue, and a hog holding the
    // single live-job slot.
    let svc = VcService::builder().workers(1).max_queued(1).max_live_jobs(1).build();
    let server = serve(svc);
    let mut client = VcClient::connect(addr_of(&server)).expect("connect");
    let hog_opts = WireOptions { lane: Some(Lane::Throughput), ..WireOptions::default() };
    let hog = client.submit(&Problem::mvc(long_running_graph()), hog_opts).unwrap();
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.service().stats().admission.live_jobs == 1
        }),
        "hog must dispatch"
    );
    let queued_g = generators::erdos_renyi(14, 0.2, 1);
    let queued =
        client.submit(&Problem::mvc(queued_g.clone()), WireOptions::default()).unwrap();
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.service().stats().admission.queued == 1
        }),
        "second submit must park in the admission queue"
    );
    let rejected =
        client.submit(&Problem::mvc(generators::path(4)), WireOptions::default()).unwrap();
    let err = expect_error(&mut client, rejected);
    assert_eq!(err.code, ErrorCode::QueueFull);
    assert_eq!(err.code.submit_error(), Some(SubmitError::QueueFull));
    client.cancel(hog).unwrap();
    let mut done = 0;
    while done < 2 {
        match client.recv().expect("drain") {
            ServerReply::Solution(sol) if sol.req_id == hog => {
                assert_eq!(sol.termination, Termination::Cancelled);
                done += 1;
            }
            ServerReply::Solution(sol) if sol.req_id == queued => {
                assert_eq!(sol.objective, oracle::mvc_size(&queued_g));
                done += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    server.shutdown();

    // Quota: a tenant at its job cap is told "quota", not "queue".
    let svc = VcService::builder()
        .workers(2)
        .tenant_quota(TenantQuota { max_jobs: 1, max_live_nodes: u64::MAX })
        .build();
    let server = serve(svc);
    let mut client = VcClient::connect(addr_of(&server)).expect("connect");
    let acme = WireOptions {
        lane: Some(Lane::Throughput),
        tenant: Some("acme".into()),
        ..WireOptions::default()
    };
    let hog = client.submit(&Problem::mvc(long_running_graph()), acme.clone()).unwrap();
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.service().stats().admission.live_jobs == 1
        }),
        "tenant hog must dispatch"
    );
    let rejected = client.submit(&Problem::mvc(generators::path(4)), acme).unwrap();
    let err = expect_error(&mut client, rejected);
    assert_eq!(err.code, ErrorCode::QuotaExceeded);
    assert_eq!(err.code.submit_error(), Some(SubmitError::QuotaExceeded));
    // `solve` surfaces the same thing as a typed client rejection.
    let rejection = client
        .solve(
            &Problem::mvc(generators::path(5)),
            WireOptions { tenant: Some("acme".into()), ..WireOptions::default() },
        )
        .expect_err("tenant is at quota");
    assert_eq!(rejection.submit_error(), Some(SubmitError::QuotaExceeded));
    assert!(matches!(rejection, ClientError::Rejected(_)));
    client.cancel(hog).unwrap();
    loop {
        if let ServerReply::Solution(sol) = client.recv().expect("drain") {
            assert_eq!(sol.req_id, hog);
            break;
        }
    }
    server.shutdown();

    // Memory pressure: past the hard limit, submits shed with the
    // memory code (checked before queue-full — a full queue under
    // pressure is a memory problem).
    let svc = VcService::builder().workers(2).mem_hard(1).build();
    let server = serve(svc);
    let mut client = VcClient::connect(addr_of(&server)).expect("connect");
    let hog = client
        .submit(
            &Problem::mvc(long_running_graph()),
            WireOptions { lane: Some(Lane::Throughput), ..WireOptions::default() },
        )
        .unwrap();
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.service().stats().admission.live_bytes > 1
        }),
        "hog never charged the ledger"
    );
    let rejected =
        client.submit(&Problem::mvc(generators::path(4)), WireOptions::default()).unwrap();
    let err = expect_error(&mut client, rejected);
    assert_eq!(err.code, ErrorCode::MemoryPressure);
    assert_eq!(err.code.submit_error(), Some(SubmitError::MemoryPressure));
    client.cancel(hog).unwrap();
    loop {
        if let ServerReply::Solution(sol) = client.recv().expect("drain") {
            assert_eq!(sol.req_id, hog);
            break;
        }
    }
    server.shutdown();
}

/// Receive replies until `req_id`'s typed error frame arrives.
fn expect_error(client: &mut VcClient, req_id: u64) -> WireErrorFrame {
    loop {
        match client.recv().expect("reply") {
            ServerReply::Error(e) if e.req_id == req_id => return e,
            ServerReply::Error(e) => panic!("error for unexpected request: {e:?}"),
            _ => continue,
        }
    }
}

/// Dropping a connection cancels its outstanding jobs: the hog stops
/// burning the pool, the ledger drains, and the server keeps serving
/// other clients with clean stats.
#[test]
fn disconnect_cancels_outstanding_jobs() {
    let server = serve(VcService::builder().workers(2).build());
    let addr = addr_of(&server);
    let mut doomed = VcClient::connect(&addr).expect("connect");
    doomed
        .submit(
            &Problem::mvc(long_running_graph()),
            WireOptions { lane: Some(Lane::Throughput), ..WireOptions::default() },
        )
        .unwrap();
    assert!(
        wait_until(Duration::from_secs(10), || {
            server.service().stats().admission.live_jobs == 1
        }),
        "hog must dispatch"
    );
    drop(doomed);
    // The reader notices the hangup, cancels the pending job, and the
    // anytime cancellation drains it from the ledger.
    assert!(
        wait_until(Duration::from_secs(15), || {
            server.service().stats().admission.live_jobs == 0
        }),
        "disconnect must cancel the outstanding hog"
    );
    assert!(
        wait_until(Duration::from_secs(10), || server.connections() == 0),
        "connection slot must be reclaimed"
    );
    // The pool is idle again: a fresh client gets a fast exact answer.
    let mut client = VcClient::connect(&addr).expect("connect");
    let g = generators::erdos_renyi(16, 0.25, 21);
    let sol = client.solve(&Problem::mvc(g.clone()), WireOptions::default()).expect("solve");
    assert_eq!(sol.objective, oracle::mvc_size(&g));
    assert_eq!(sol.termination, Termination::Complete);
    // Stats scrape over the wire agrees with the in-process ledger.
    let scraped = client.stats().expect("stats scrape");
    assert_eq!(scraped.admission.live_jobs, 0);
    assert!(scraped.admission.dispatched_latency + scraped.admission.dispatched_throughput >= 2);
    server.shutdown();
}
