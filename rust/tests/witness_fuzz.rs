//! Differential witness-verification fuzz suite: every extracted
//! witness — parallel one-shot, resident service, sequential — is
//! verified vertex-by-vertex against the *original* (pre-prep) graph,
//! across both schedulers and multiple worker counts, on seeded random
//! families plus the nested `split_gadget` worst cases.
//!
//! Deterministic seeds; `CAVC_FUZZ_CASES` scales the case count for the
//! nightly/CI deep run (default 60 per property).

use cavc::graph::{generators, Graph};
use cavc::solver::witness::{verify_cover, verify_independent_set};
use cavc::solver::{
    oracle, solve_mvc, solve_pvc, JobOptions, Problem, SchedulerKind, SolverConfig, Termination,
    VcService,
};
use cavc::util::SplitMix64;

const SEED: u64 = 0x717E55_0001;
const SCHEDULERS: [SchedulerKind; 2] = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];
const WORKER_COUNTS: [usize; 3] = [1, 4, 16];

fn fuzz_cases() -> usize {
    std::env::var("CAVC_FUZZ_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(60)
}

/// One deterministic case: random families that reduce, split, and nest.
fn random_case(rng: &mut SplitMix64) -> (Graph, String) {
    let kind = rng.index(5);
    let seed = rng.next_u64();
    match kind {
        0 => {
            let n = rng.range(6, 24);
            let p = 0.08 + rng.next_f64() * 0.3;
            (generators::erdos_renyi(n, p, seed), format!("er({n},{p:.2},{seed})"))
        }
        1 => {
            let n = rng.range(4, 28);
            (generators::random_tree(n, seed), format!("tree({n},{seed})"))
        }
        2 => {
            // ≥ 3 disconnected parts: the engine must reassemble a cover
            // across at least three component-local subproblems
            let parts = rng.range(3, 6);
            (
                generators::union_of_random(parts, 3, 7, 0.3, seed),
                format!("union({parts},{seed})"),
            )
        }
        3 => {
            let n = rng.range(8, 18);
            let p = 0.15 + rng.next_f64() * 0.2;
            (generators::grid(3, n / 3 + 2, p, seed), format!("grid(3x{},{seed})", n / 3 + 2))
        }
        _ => {
            let n = rng.range(10, 22);
            (generators::barabasi_albert(n, 2, seed), format!("ba({n},{seed})"))
        }
    }
}

fn extract_cfg(workers: usize, sched: SchedulerKind) -> SolverConfig {
    let mut cfg = SolverConfig::proposed().with_workers(workers).with_scheduler(sched);
    cfg.extract_cover = true;
    cfg
}

/// MVC one-shot: witness valid, |witness| == objective == oracle.
#[test]
fn fuzz_mvc_witnesses_match_oracle() {
    let mut rng = SplitMix64::new(SEED);
    let mut ran = 0usize;
    for case in 0..fuzz_cases() {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        let workers = WORKER_COUNTS[case % WORKER_COUNTS.len()];
        let sched = SCHEDULERS[case % SCHEDULERS.len()];
        let cfg = extract_cfg(workers, sched);
        let r = solve_mvc(&g, &cfg);
        assert!(!r.timed_out, "case {case} {tag}: timed out");
        assert_eq!(r.best, opt, "case {case} {tag} ({workers}w {})", sched.name());
        let c = r.cover.expect("extraction must produce a witness");
        assert_eq!(c.len() as u32, opt, "case {case} {tag}: |witness| != objective");
        verify_cover(&g, &c)
            .unwrap_or_else(|e| panic!("case {case} {tag} ({workers}w {}): {e}", sched.name()));
        ran += 1;
    }
    assert!(ran * 2 >= fuzz_cases(), "only {ran} cases ran; generator drift?");
}

/// PVC: found covers respect the bound k and verify; k below the
/// optimum stays infeasible.
#[test]
fn fuzz_pvc_witnesses_respect_k() {
    let mut rng = SplitMix64::new(SEED ^ 0xBEEF);
    for case in 0..fuzz_cases() {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        let workers = WORKER_COUNTS[case % WORKER_COUNTS.len()];
        let sched = SCHEDULERS[case % SCHEDULERS.len()];
        let cfg = extract_cfg(workers, sched);
        for k in [opt, opt + 1] {
            let r = solve_pvc(&g, k, &cfg);
            assert!(r.found, "case {case} {tag}: missed k={k}");
            let c = r.cover.unwrap_or_else(|| panic!("case {case} {tag}: no cover at k={k}"));
            assert!(c.len() as u32 <= k, "case {case} {tag}: |cover| > k");
            verify_cover(&g, &c).unwrap_or_else(|e| panic!("case {case} {tag} k={k}: {e}"));
        }
        assert!(
            !solve_pvc(&g, opt.saturating_sub(1), &cfg).found,
            "case {case} {tag}: found below optimum"
        );
    }
}

/// Service jobs with `extract_witness`: MVC/PVC/MIS all return verified
/// witnesses, concurrently, on both resident runtimes.
#[test]
fn fuzz_service_jobs_return_verified_witnesses() {
    let mut rng = SplitMix64::new(SEED ^ 0x5E41);
    let mut cases: Vec<(Graph, u32, String)> = Vec::new();
    for case in 0..fuzz_cases() {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 || g.num_edges() == 0 {
            continue;
        }
        let opt = oracle::mvc_size(&g);
        cases.push((g, opt, format!("case {case} {tag}")));
    }
    assert!(cases.len() * 2 >= fuzz_cases(), "generator drift");
    let opts = || JobOptions { extract_witness: true, ..JobOptions::default() };
    for sched in SCHEDULERS {
        let svc = VcService::builder().workers(4).scheduler(sched).build();
        let handles: Vec<_> = cases
            .iter()
            .enumerate()
            .map(|(i, (g, opt, _))| match i % 3 {
                0 => svc.submit_with(Problem::mvc(g.clone()), opts()),
                1 => svc.submit_with(Problem::pvc(g.clone(), *opt), opts()),
                _ => svc.submit_with(Problem::mis(g.clone()), opts()),
            })
            .collect();
        for (i, h) in handles.iter().enumerate() {
            let (g, opt, tag) = &cases[i];
            let sol = h.wait();
            assert_eq!(sol.termination, Termination::Complete, "{tag} ({})", sched.name());
            let w = sol
                .witness
                .as_ref()
                .unwrap_or_else(|| panic!("{tag} ({}): no witness", sched.name()));
            assert_eq!(
                sol.witness_verified,
                Some(true),
                "{tag} ({}): witness_verified",
                sched.name()
            );
            match i % 3 {
                0 => {
                    assert_eq!(sol.objective, *opt, "{tag}: mvc objective");
                    assert_eq!(w.len() as u32, *opt, "{tag}: |witness| != objective");
                    verify_cover(g, w).unwrap_or_else(|e| panic!("{tag}: {e}"));
                }
                1 => {
                    assert!(sol.feasible, "{tag}: pvc missed k=opt");
                    assert!(w.len() as u32 <= *opt, "{tag}: pvc witness above k");
                    verify_cover(g, w).unwrap_or_else(|e| panic!("{tag}: {e}"));
                }
                _ => {
                    let alpha = g.num_vertices() as u32 - *opt;
                    assert_eq!(sol.objective, alpha, "{tag}: alpha");
                    assert_eq!(w.len() as u32, alpha, "{tag}: |mis witness| != alpha");
                    verify_independent_set(g, w).unwrap_or_else(|e| panic!("{tag}: {e}"));
                }
            }
        }
    }
}

/// Nested split gadgets: the worst case for cover reassembly — every
/// hub branch cascades into nested component splits whose renumbered
/// subproblems must translate back through the whole view chain.
#[test]
fn fuzz_split_gadget_nested_reassembly() {
    // depth 2 = 43 vertices, a chain of ≥ 3 nested splits during search
    for depth in [1usize, 2] {
        let g = generators::split_gadget(depth);
        // sequential extraction is the reference (oracle is too slow
        // past 64 vertices; the gadget sizes stay within it at depth ≤ 2)
        let opt = oracle::mvc_size(&g);
        for sched in SCHEDULERS {
            for workers in WORKER_COUNTS {
                for induce in [0.0, 1.0] {
                    let cfg = extract_cfg(workers, sched).with_induce_threshold(induce);
                    let r = solve_mvc(&g, &cfg);
                    let tag =
                        format!("gadget({depth}) {}w {} induce={induce}", workers, sched.name());
                    assert_eq!(r.best, opt, "{tag}");
                    let c = r.cover.expect("witness");
                    assert_eq!(c.len() as u32, opt, "{tag}");
                    verify_cover(&g, &c).unwrap_or_else(|e| panic!("{tag}: {e}"));
                }
            }
        }
    }
}

/// MIS complements through the one-shot pipeline stay independent.
#[test]
fn fuzz_mis_complements_independent() {
    let mut rng = SplitMix64::new(SEED ^ 0x1715);
    for case in 0..fuzz_cases().min(30) {
        let (g, tag) = random_case(&mut rng);
        if g.num_vertices() > 64 {
            continue;
        }
        let workers = WORKER_COUNTS[case % WORKER_COUNTS.len()];
        let sched = SCHEDULERS[case % SCHEDULERS.len()];
        let cfg = extract_cfg(workers, sched);
        let r = cavc::solver::mis::solve_mis(&g, &cfg);
        let alpha = g.num_vertices() as u32 - oracle::mvc_size(&g);
        assert_eq!(r.alpha, alpha, "case {case} {tag}");
        let set = r.set.expect("mis witness");
        assert_eq!(set.len() as u32, alpha, "case {case} {tag}");
        verify_independent_set(&g, &set).unwrap_or_else(|e| panic!("case {case} {tag}: {e}"));
    }
}

/// The fuzz case generator is deterministic (reproducibility contract).
#[test]
fn fuzz_cases_are_deterministic() {
    let mut a = SplitMix64::new(SEED);
    let mut b = SplitMix64::new(SEED);
    for case in 0..fuzz_cases() {
        let (ga, ta) = random_case(&mut a);
        let (gb, tb) = random_case(&mut b);
        assert_eq!(ta, tb, "case {case}");
        assert_eq!(ga, gb, "case {case}");
    }
}
